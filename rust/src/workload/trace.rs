//! `TensorTrace` — the self-describing empirical tensor trace format.
//!
//! A trace carries one tensor captured from a real (or synthetically
//! generated) workload: a name, a shape, and an f32/f64 payload. Two
//! encodings are accepted, distinguished by the first byte of the file:
//!
//! **Binary** (what `tools/export_trace.py` writes):
//!
//! ```text
//! offset  size        field
//! 0       4           magic  b"GRTT"
//! 4       4           format version, u32 LE (currently 1)
//! 8       4           header length H, u32 LE
//! 12      H           JSON header: {"name": str, "dtype": "f32"|"f64",
//!                                   "shape": [d0, d1, ...]}
//! 12+H    N*4 or N*8  payload, little-endian, N = product(shape)
//! ```
//!
//! **JSON** (first byte `{`, convenient for tests and tiny traces):
//!
//! ```text
//! {"name": "t", "shape": [4], "values": [0.5, -0.25, 0.0, 1.0]}
//! ```
//!
//! Parsing is strict: bad magic, unsupported versions, truncated or
//! oversized payloads, shape/payload count mismatches, and non-finite
//! values (NaN/Inf) are all hard errors — a trace that loads is safe to
//! fit and simulate from.
//!
//! # Content hash
//!
//! [`TensorTrace::content_hash`] is an FNV-1a 64 digest of the dtype, the
//! shape, and the exact payload bit patterns. The **name is deliberately
//! excluded**: like the experiment `id` in [`crate::server::proto::spec_key`],
//! it labels reports but cannot influence any computed number, so two
//! differently-named copies of the same tensor share one cache entry in
//! `grcim serve`.
//!
//! # Example
//!
//! ```
//! use grcim::workload::TensorTrace;
//!
//! let t = TensorTrace::from_f32("acts", vec![2, 2], vec![0.5, -1.0, 0.25, 0.0]).unwrap();
//! assert_eq!(t.len(), 4);
//! // binary round trip is bit-exact and hash-stable
//! let again = TensorTrace::from_bytes(&t.to_bytes()).unwrap();
//! assert_eq!(again.values(), t.values());
//! assert_eq!(again.content_hash(), t.content_hash());
//! // the name does not participate in the hash
//! let renamed = TensorTrace::from_f32("other", vec![2, 2], vec![0.5, -1.0, 0.25, 0.0]).unwrap();
//! assert_eq!(renamed.content_hash(), t.content_hash());
//! ```

use crate::config::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Magic bytes opening a binary trace file.
pub const MAGIC: &[u8; 4] = b"GRTT";
/// Binary trace format version this build reads and writes.
pub const VERSION: u32 = 1;

/// Element type of a trace payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit IEEE-754 (what the engines consume; the common capture type).
    F32,
    /// 64-bit IEEE-754 (lossless captures; JSON traces parse as f64).
    F64,
}

impl Dtype {
    /// The header string for this dtype (`"f32"` / `"f64"`).
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    /// Payload bytes per element.
    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f64" => Ok(Dtype::F64),
            other => bail!("unsupported trace dtype '{other}' (f32|f64)"),
        }
    }
}

/// One empirical tensor trace: name, shape, and a validated finite
/// payload (widened to f64 in memory; the original bit patterns feed the
/// content hash).
#[derive(Debug, Clone)]
pub struct TensorTrace {
    name: String,
    shape: Vec<usize>,
    dtype: Dtype,
    values: Vec<f64>,
    content_hash: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash dtype + shape + raw payload bit patterns (name excluded — see the
/// module docs).
fn hash_content(dtype: Dtype, shape: &[usize], payload_bits: &[u8]) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, dtype.name().as_bytes());
    h = fnv1a(h, &(shape.len() as u64).to_le_bytes());
    for &d in shape {
        h = fnv1a(h, &(d as u64).to_le_bytes());
    }
    fnv1a(h, payload_bits)
}

fn shape_count(name: &str, shape: &[usize]) -> Result<usize> {
    if shape.is_empty() {
        bail!("trace '{name}': shape must have at least one dimension");
    }
    let mut count = 1usize;
    for &d in shape {
        if d == 0 {
            bail!("trace '{name}': zero-sized dimension in shape {shape:?}");
        }
        count = count
            .checked_mul(d)
            .with_context(|| format!("trace '{name}': shape {shape:?} overflows"))?;
    }
    Ok(count)
}

fn ensure_finite(name: &str, values: &[f64]) -> Result<()> {
    for (i, v) in values.iter().enumerate() {
        if !v.is_finite() {
            bail!("trace '{name}': non-finite value {v} at index {i}");
        }
    }
    Ok(())
}

impl TensorTrace {
    /// Build a trace from f32 data (validates shape/count and finiteness).
    pub fn from_f32(
        name: impl Into<String>,
        shape: Vec<usize>,
        data: Vec<f32>,
    ) -> Result<TensorTrace> {
        let name = name.into();
        let count = shape_count(&name, &shape)?;
        if count != data.len() {
            bail!(
                "trace '{name}': shape {shape:?} implies {count} values, \
                 payload has {}",
                data.len()
            );
        }
        let values: Vec<f64> = data.iter().map(|&v| v as f64).collect();
        ensure_finite(&name, &values)?;
        let mut bits = Vec::with_capacity(data.len() * 4);
        for v in &data {
            bits.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let content_hash = hash_content(Dtype::F32, &shape, &bits);
        Ok(TensorTrace { name, shape, dtype: Dtype::F32, values, content_hash })
    }

    /// Build a trace from f64 data (validates shape/count and finiteness).
    pub fn from_f64(
        name: impl Into<String>,
        shape: Vec<usize>,
        values: Vec<f64>,
    ) -> Result<TensorTrace> {
        let name = name.into();
        let count = shape_count(&name, &shape)?;
        if count != values.len() {
            bail!(
                "trace '{name}': shape {shape:?} implies {count} values, \
                 payload has {}",
                values.len()
            );
        }
        ensure_finite(&name, &values)?;
        let mut bits = Vec::with_capacity(values.len() * 8);
        for v in &values {
            bits.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let content_hash = hash_content(Dtype::F64, &shape, &bits);
        Ok(TensorTrace { name, shape, dtype: Dtype::F64, values, content_hash })
    }

    /// Trace label (reports only; excluded from the content hash).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Tensor shape as captured.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Payload element type.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Payload values, widened to f64, in capture order.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True for a zero-element trace (unreachable for parsed traces —
    /// empty shapes are rejected — but part of the slice-like API).
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// FNV-1a 64 digest of dtype + shape + exact payload bits. This is the
    /// identity `grcim serve` caches workload results under.
    pub fn content_hash(&self) -> u64 {
        self.content_hash
    }

    /// Read a trace file, dispatching on the first byte: `{` parses the
    /// JSON form, anything else the binary form.
    pub fn read(path: &Path) -> Result<TensorTrace> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        if bytes.first() == Some(&b'{') {
            let text = std::str::from_utf8(&bytes)
                .with_context(|| format!("trace {} is not UTF-8", path.display()))?;
            Self::from_json_str(text)
                .with_context(|| format!("parsing JSON trace {}", path.display()))
        } else {
            Self::from_bytes(&bytes)
                .with_context(|| format!("parsing binary trace {}", path.display()))
        }
    }

    /// Parse the binary encoding (see the module docs for the layout).
    pub fn from_bytes(bytes: &[u8]) -> Result<TensorTrace> {
        if bytes.len() < 12 {
            bail!("truncated trace: {} bytes, header needs 12", bytes.len());
        }
        if &bytes[0..4] != MAGIC {
            bail!("bad magic {:?} (expected {MAGIC:?})", &bytes[0..4]);
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported trace version {version} (this build reads {VERSION})");
        }
        let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let Some(header_bytes) = bytes.get(12..12 + hlen) else {
            bail!("truncated trace: header says {hlen} bytes, file ends early");
        };
        let header = std::str::from_utf8(header_bytes)
            .context("trace header is not UTF-8")?;
        let j = Json::parse(header).context("trace header is not valid JSON")?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("trace header missing 'name'")?
            .to_string();
        let dtype = Dtype::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .context("trace header missing 'dtype'")?,
        )?;
        let shape_json = j.get("shape").context("trace header missing 'shape'")?;
        let mut shape = Vec::new();
        for d in shape_json.items() {
            shape.push(
                d.as_usize()
                    .context("trace header shape must be an array of integers")?,
            );
        }
        let count = shape_count(&name, &shape)?;
        let payload = &bytes[12 + hlen..];
        let need = count * dtype.size();
        if payload.len() < need {
            bail!(
                "trace '{name}': truncated payload — shape {shape:?} needs \
                 {need} bytes, got {}",
                payload.len()
            );
        }
        if payload.len() > need {
            bail!(
                "trace '{name}': {} trailing bytes after the payload",
                payload.len() - need
            );
        }
        match dtype {
            Dtype::F32 => {
                let data: Vec<f32> = payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Self::from_f32(name, shape, data)
            }
            Dtype::F64 => {
                let data: Vec<f64> = payload
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Self::from_f64(name, shape, data)
            }
        }
    }

    /// Parse the JSON encoding: `{"name", "shape"?, "values": [...]}`
    /// (shape defaults to `[values.len()]`; values parse as f64).
    pub fn from_json_str(text: &str) -> Result<TensorTrace> {
        let j = Json::parse(text).context("trace is not valid JSON")?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("json-trace")
            .to_string();
        let items = j.get("values").context("JSON trace needs a 'values' array")?;
        let mut values = Vec::new();
        for v in items.items() {
            values.push(v.as_f64().context("trace values must be numbers")?);
        }
        if values.is_empty() {
            bail!("trace '{name}': 'values' array is empty");
        }
        let shape = match j.get("shape") {
            None => vec![values.len()],
            Some(s) => {
                let mut shape = Vec::new();
                for d in s.items() {
                    shape.push(
                        d.as_usize().context("trace shape must be integers")?,
                    );
                }
                shape
            }
        };
        Self::from_f64(name, shape, values)
    }

    /// Serialize into the binary encoding (round-trips bit-exactly through
    /// [`TensorTrace::from_bytes`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        let shape: Vec<Json> = self
            .shape
            .iter()
            .map(|&d| Json::Num(d as f64))
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("dtype".to_string(), Json::Str(self.dtype.name().to_string()));
        m.insert("shape".to_string(), Json::Arr(shape));
        let header = Json::Obj(m).to_string();
        let mut out = Vec::with_capacity(12 + header.len() + self.values.len() * 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for &v in &self.values {
            match self.dtype {
                Dtype::F32 => {
                    out.extend_from_slice(&(v as f32).to_bits().to_le_bytes())
                }
                Dtype::F64 => out.extend_from_slice(&v.to_bits().to_le_bytes()),
            }
        }
        out
    }

    /// Write the binary encoding to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing trace {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TensorTrace {
        TensorTrace::from_f32(
            "t",
            vec![2, 3],
            vec![0.5, -0.25, 0.0, 1.0, -1.0, 0.125],
        )
        .unwrap()
    }

    #[test]
    fn binary_round_trip_is_bit_exact() {
        let t = small();
        let bytes = t.to_bytes();
        let again = TensorTrace::from_bytes(&bytes).unwrap();
        assert_eq!(again.name(), "t");
        assert_eq!(again.shape(), &[2, 3]);
        assert_eq!(again.dtype(), Dtype::F32);
        assert_eq!(again.len(), 6);
        for (a, b) in again.values().iter().zip(t.values()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(again.content_hash(), t.content_hash());
    }

    #[test]
    fn f64_round_trip_and_file_io() {
        let t = TensorTrace::from_f64("w", vec![4], vec![0.1, 0.2, -0.3, 0.4])
            .unwrap();
        let dir = std::env::temp_dir().join("grcim_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.grtt");
        t.write(&path).unwrap();
        let again = TensorTrace::read(&path).unwrap();
        assert_eq!(again.dtype(), Dtype::F64);
        assert_eq!(again.values(), t.values());
        assert_eq!(again.content_hash(), t.content_hash());
    }

    #[test]
    fn json_form_parses_and_defaults_shape() {
        let t = TensorTrace::from_json_str(
            r#"{"name":"j","values":[0.5,-0.5,0.25]}"#,
        )
        .unwrap();
        assert_eq!(t.shape(), &[3]);
        assert_eq!(t.values(), &[0.5, -0.5, 0.25]);
        // explicit shape must match the value count
        assert!(TensorTrace::from_json_str(
            r#"{"name":"j","shape":[2],"values":[1,2,3]}"#
        )
        .is_err());
        // file dispatch: a JSON file read through TensorTrace::read
        let dir = std::env::temp_dir().join("grcim_trace_test_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        std::fs::write(&path, r#"{"name":"j","values":[1, -1]}"#).unwrap();
        assert_eq!(TensorTrace::read(&path).unwrap().len(), 2);
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut bytes = small().to_bytes();
        bytes[0] = b'X';
        let err = TensorTrace::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let mut bytes = small().to_bytes();
        bytes[4] = 99;
        let err = TensorTrace::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported trace version"), "{err}");
    }

    #[test]
    fn rejects_truncated_payload_and_trailing_bytes() {
        let bytes = small().to_bytes();
        let err = TensorTrace::from_bytes(&bytes[..bytes.len() - 3])
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated payload"), "{err}");

        let mut extra = bytes.clone();
        extra.push(0);
        let err = TensorTrace::from_bytes(&extra).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");

        // header-level truncation
        let err = TensorTrace::from_bytes(&bytes[..8]).unwrap_err().to_string();
        assert!(err.contains("truncated trace"), "{err}");
    }

    #[test]
    fn rejects_shape_payload_mismatch() {
        let err = TensorTrace::from_f32("t", vec![4], vec![1.0, 2.0])
            .unwrap_err()
            .to_string();
        assert!(err.contains("implies 4 values"), "{err}");
        assert!(TensorTrace::from_f64("t", vec![0], vec![]).is_err());
        assert!(TensorTrace::from_f64("t", vec![], vec![]).is_err());
    }

    #[test]
    fn rejects_non_finite_values() {
        let err = TensorTrace::from_f32("t", vec![2], vec![1.0, f32::NAN])
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-finite"), "{err}");
        assert!(err.contains("index 1"), "{err}");
        assert!(
            TensorTrace::from_f64("t", vec![1], vec![f64::INFINITY]).is_err()
        );
        assert!(TensorTrace::from_json_str(
            r#"{"name":"j","values":[1e999]}"#
        )
        .is_err());
    }

    #[test]
    fn content_hash_covers_payload_shape_dtype_but_not_name() {
        let base = small();
        let renamed = TensorTrace::from_f32(
            "other-name",
            vec![2, 3],
            vec![0.5, -0.25, 0.0, 1.0, -1.0, 0.125],
        )
        .unwrap();
        assert_eq!(base.content_hash(), renamed.content_hash());

        let reshaped = TensorTrace::from_f32(
            "t",
            vec![6],
            vec![0.5, -0.25, 0.0, 1.0, -1.0, 0.125],
        )
        .unwrap();
        assert_ne!(base.content_hash(), reshaped.content_hash());

        let perturbed = TensorTrace::from_f32(
            "t",
            vec![2, 3],
            vec![0.5, -0.25, 0.0, 1.0, -1.0, 0.1250001],
        )
        .unwrap();
        assert_ne!(base.content_hash(), perturbed.content_hash());

        let widened = TensorTrace::from_f64(
            "t",
            vec![2, 3],
            vec![0.5, -0.25, 0.0, 1.0, -1.0, 0.125],
        )
        .unwrap();
        assert_ne!(base.content_hash(), widened.content_hash());
    }
}
