//! `grcim` — CLI launcher for the GR-CIM design-space exploration
//! framework.
//!
//! Subcommands: `figures`, `energy`, `sweep`, `workload`, `layer`,
//! `model`, `explore`, `serve`, `query`, `loadgen`, `validate`, `info`.
//! The full
//! flag and
//! wire-protocol reference
//! lives in `docs/CLI.md`; the module map in `docs/ARCHITECTURE.md`; the
//! paper-equation-to-code map in `docs/THEORY.md`.

use anyhow::{bail, Context, Result};
use grcim::cli::sweep::{LayerParams, ModelParams, SweepPlan};
use grcim::cli::{fig_list, flags, Args};
use grcim::config::Json;
use grcim::coordinator::{run_campaign, samples_for_ci, CampaignConfig};
#[cfg(feature = "pjrt")]
use grcim::distributions::Distribution;
use grcim::distributions::Sampler;
use grcim::figures::{FigureCtx, ALL};
#[cfg(feature = "pjrt")]
use grcim::formats::FpFormat;
#[cfg(feature = "pjrt")]
use grcim::mac::FormatPair;
use grcim::report::Table;
use grcim::runtime::{build_engine, ArtifactRegistry, EngineKind};
use grcim::server::{proto, ServeConfig, Server, DEFAULT_ADDR};
use grcim::spec::{required_enob, Arch, SpecConfig};
use grcim::util::{self, Level};
use std::path::PathBuf;

const USAGE: &str = "\
grcim — Gain-Ranging CIM design-space exploration (paper reproduction)

USAGE: grcim <command> [flags]          full reference: docs/CLI.md

COMMANDS:
  figures    regenerate paper figures/tables   --fig all|fig4|...|table1
  energy     energy model at a spec point      --dr <dB> --sqnr <dB>
             [--sampler plain|antithetic|stratified] [--target-ci dB]
  sweep      run a TOML campaign               grcim sweep <config.toml>
  workload   analyze an empirical trace        grcim workload --trace t.grtt
  layer      layer-scale GEMM on the tiled array mapper
             grcim layer --shape mlp-up:4096 --arch gr [--tokens N]
             (conv via im2col: --shape conv:<Cout>x<Cin>x<kH>x<kW>@<H>x<W>)
             [--nr N] [--nc N] [--ne N] [--nm N] [--dist NAME|empirical:t]
  model      chain tile layers into a full-network energy report
             grcim model --model mlp:<d0>x<d1>x...|block:<d>|<shape,...>
             |transformer:<d>x<heads>x<layers>|decode:<d>x<heads>x<ctx>
             [--fit] [--tokens N] [--arch A] [--nr N] [--nc N] [--ne N]
             [--nm N] [--dist NAME|empirical:t]
  explore    design-space Pareto explorer      grcim explore --plan p.toml
             [--out results/pareto.jsonl] [--ckpt run.ckpt]
             resume a killed run: grcim explore --resume run.ckpt
  serve      resident campaign service (NDJSON/TCP, cached + coalesced)
             event-loop core: [--mux N] [--compute N] [--queue N]
  query      client for a running serve        grcim query energy --dr 36
             kinds: energy|sweep|figure|workload|layer|model|pareto
             |metrics|info
             raw mode: grcim query --json '<request>' (non-empty object;
             --seed must fit in 2^53 — JSON numbers are f64)
  loadgen    drive a running serve with concurrent connections
             grcim loadgen --conns 1000 --requests 4 --mix energy,info
             [--deadline MS] [--loris-ms MS] [--json '<request>']
  validate   PJRT artifacts vs the Rust oracle (--features pjrt builds)
  info       artifact + engine status

COMMON FLAGS: --engine rust|pjrt|auto, --artifacts DIR, --workers N,
  --seed N, --samples N, --verbose, --quiet
";

/// The artifact directory for this invocation: `--artifacts`, else
/// `$GRCIM_ARTIFACTS`, else `./artifacts` (one resolution shared by every
/// subcommand that touches artifacts).
fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ArtifactRegistry::default_dir)
}

fn campaign_from_args(args: &Args) -> Result<CampaignConfig> {
    Ok(CampaignConfig {
        engine: EngineKind::parse(args.get_or("engine", "auto"))?,
        artifacts_dir: artifacts_dir(args),
        workers: args.get_usize("workers", 0)?,
        seed: args.get_u64("seed", 0xC1A0_57A7)?,
    })
}

fn cmd_figures(args: &Args) -> Result<()> {
    args.ensure_known(flags::FIGURES)?;
    args.ensure_known_switches(&[])?;
    let mut ctx = FigureCtx {
        campaign: campaign_from_args(args)?,
        samples: args.get_usize("samples", 65_536)?,
        out_dir: PathBuf::from(args.get_or("out", "results")),
    };
    if args.has("quick") {
        ctx = ctx.quick();
    }
    let ids = fig_list(args.get_or("fig", "all"), ALL);
    let mut failed = Vec::new();
    for id in &ids {
        let t = util::Timer::new(format!("figure {id}"));
        let fr = grcim::figures::run(id, &ctx)?;
        let text = fr.emit(&ctx.out_dir)?;
        println!("{text}");
        grcim::info!("{id} done in {:.1}s", t.elapsed_s());
        if !fr.all_hold() {
            failed.push(id.to_string());
        }
    }
    if !failed.is_empty() {
        bail!("paper-shape checks failed for: {}", failed.join(", "));
    }
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    args.ensure_known(flags::ENERGY)?;
    args.ensure_known_switches(&[])?;
    let dr = args.get_f64("dr", 30.1)?;
    let sqnr = args.get_f64("sqnr", 22.83)?;
    let sampler = match args.get("sampler") {
        None => Sampler::default(),
        Some(s) => Sampler::parse(s).map_err(anyhow::Error::msg)?,
    };
    let ctx = FigureCtx {
        campaign: campaign_from_args(args)?,
        samples: args.get_usize("samples", 16_384)?,
        out_dir: PathBuf::from("results"),
    };
    let p = grcim::figures::fig12::SpecPoint::from_db(dr, sqnr);
    if args.get("target-ci").is_some() {
        return cmd_energy_target_ci(args, &ctx, &p, dr, sqnr);
    }
    let tech = grcim::energy::TechParams::default();
    let res = grcim::figures::fig12::evaluate_points_with(
        &ctx, &[p], ctx.samples, sampler, &tech,
    )?;
    let Some(r) = &res[0] else {
        bail!("spec point (DR {dr} dB, SQNR {sqnr} dB) is left of the INT line");
    };
    let mut t = Table::new(
        format!("energy @ DR={dr} dB, SQNR={sqnr} dB"),
        &["arch", "enob", "fJ/op", "adc", "dac", "cells", "logic+tree+mult"],
    );
    t.row(vec![
        "conventional".into(),
        Table::f(r.enob_conv),
        Table::f(r.e_conv.total()),
        Table::f(r.e_conv.adc),
        Table::f(r.e_conv.dac),
        Table::f(r.e_conv.cells),
        Table::f(r.e_conv.exp_logic + r.e_conv.tree + r.e_conv.norm_mult),
    ]);
    for (arch, enob, b) in &r.gr_all {
        t.row(vec![
            arch.name().into(),
            Table::f(*enob),
            Table::f(b.total()),
            Table::f(b.adc),
            Table::f(b.dac),
            Table::f(b.cells),
            Table::f(b.exp_logic + b.tree + b.norm_mult),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

/// `grcim energy --target-ci <dB>`: instead of the energy table, report
/// how many Monte-Carlo samples each estimator mode (plain, antithetic,
/// stratified) needs for a ±h dB SQNR confidence interval at this spec
/// point — for both of the point's experiments (INT/narrow-bounds and
/// FP/full-scale). Pilot runs are deterministic in the campaign seed,
/// so the numbers are reproducible (and golden-pinned in the tests).
fn cmd_energy_target_ci(
    args: &Args,
    ctx: &FigureCtx,
    p: &grcim::figures::fig12::SpecPoint,
    dr: f64,
    sqnr: f64,
) -> Result<()> {
    use grcim::figures::fig12;
    let h = args.get_f64("target-ci", 0.0)?;
    if !(h > 0.0) {
        bail!("--target-ci must be a positive CI half-width in dB, got {h}");
    }
    let (Some(fp), Some(int)) = (p.fp_format(), p.int_format()) else {
        bail!("spec point (DR {dr} dB, SQNR {sqnr} dB) is left of the INT line");
    };
    let engine =
        build_engine(ctx.campaign.engine, &ctx.campaign.artifacts_dir)?;
    let w_fmt = fig12::weight_fmt();
    let w_dist = grcim::distributions::Distribution::max_entropy(w_fmt);
    let experiments = [
        ("int", grcim::coordinator::ExperimentSpec {
            id: "ci-int".to_string(),
            fmts: grcim::mac::FormatPair::new(int, w_fmt),
            dist_x: fig12::narrow_bounds_dist(fp),
            dist_w: w_dist.clone(),
            nr: fig12::NR,
            samples: ctx.samples,
            sampler: Default::default(),
        }),
        ("fp", grcim::coordinator::ExperimentSpec {
            id: "ci-fp".to_string(),
            fmts: grcim::mac::FormatPair::new(fp, w_fmt),
            dist_x: grcim::distributions::Distribution::Uniform,
            dist_w: w_dist,
            nr: fig12::NR,
            samples: ctx.samples,
            sampler: Default::default(),
        }),
    ];
    let mut t = Table::new(
        format!("samples for a ±{h} dB SQNR CI @ DR={dr} dB, SQNR={sqnr} dB"),
        &["experiment", "sampler", "sqnr (dB)", "std (dB)", "samples needed"],
    );
    for (label, spec) in &experiments {
        for est in samples_for_ci(engine.as_ref(), spec, ctx.campaign.seed, h)? {
            t.row(vec![
                (*label).into(),
                est.sampler.name().into(),
                Table::f(est.sqnr_db_mean),
                Table::f(est.sqnr_db_std),
                est.required_samples.to_string(),
            ]);
        }
    }
    println!("{}", t.to_markdown());
    Ok(())
}

/// `grcim workload --trace <file>`: fit an empirical tensor trace and
/// print/persist the workload analysis (summary, Fig. 9-style SQNR sweep,
/// conventional-vs-GR energy bounds). Exits non-zero if one of the
/// distribution-independent invariant checks fails.
fn cmd_workload(args: &Args) -> Result<()> {
    args.ensure_known(flags::WORKLOAD)?;
    args.ensure_known_switches(&[])?;
    let path = args
        .get("trace")
        .map(String::from)
        .or_else(|| args.positional.first().cloned())
        .context("workload needs a trace: grcim workload --trace <file>")?;
    let trace = grcim::workload::TensorTrace::read(std::path::Path::new(&path))?;
    let fit = grcim::util::sync::Arc::new(grcim::workload::EmpiricalDist::fit(&trace)?);
    let campaign = campaign_from_args(args)?;
    let samples = args.get_usize("samples", 16_384)?;
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    let t = util::Timer::new("workload");
    let fr = grcim::workload::report(&fit, &campaign, samples)?;
    let text = fr.emit(&out_dir)?;
    println!("{text}");
    grcim::info!("workload done in {:.1}s", t.elapsed_s());
    if !fr.all_hold() {
        bail!("workload invariant checks failed (see table above)");
    }
    Ok(())
}

/// Build the [`LayerParams`] shared by `grcim layer` and `grcim query
/// layer` from flags (defaults from [`LayerParams::default`]).
fn layer_params(args: &Args, shape: String) -> Result<LayerParams> {
    let d = LayerParams::default();
    Ok(LayerParams {
        shape,
        tokens: args.get_usize("tokens", d.tokens)?,
        arch: args.get_or("arch", d.arch.as_str()).to_string(),
        nr: args.get_usize("nr", d.nr)?,
        nc: args.get_usize("nc", d.nc)?,
        n_e: args.get_f64("ne", d.n_e)?,
        n_m: args.get_f64("nm", d.n_m)?,
        distribution: args.get_or("dist", d.distribution.as_str()).to_string(),
    })
}

/// `grcim layer --shape <shape>`: evaluate one layer-scale GEMM on the
/// tiled array mapper (per-tile spec-solved ADCs, per-tile energy,
/// digital partial-sum reduction) and print/persist the report. Exits
/// non-zero if an invariant check fails.
fn cmd_layer(args: &Args) -> Result<()> {
    args.ensure_known(flags::LAYER)?;
    args.ensure_known_switches(&[])?;
    let shape = args
        .get("shape")
        .map(String::from)
        .or_else(|| args.positional.first().cloned())
        .context("layer needs a shape: grcim layer --shape mlp-up:4096")?;
    let spec = layer_params(args, shape)?.resolve()?;
    let campaign = campaign_from_args(args)?;
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    let t = util::Timer::new("layer");
    let res = grcim::tile::run_layer(&spec, &campaign)?;
    let fr = res.report.to_figure_result();
    let text = fr.emit(&out_dir)?;
    println!("{text}");
    grcim::info!(
        "layer done in {:.1}s ({} tiles, {:.2} fJ/MAC)",
        t.elapsed_s(),
        res.report.tiles.len(),
        res.report.fj_per_mac()
    );
    if !fr.all_hold() {
        bail!("layer invariant checks failed (see table above)");
    }
    Ok(())
}

/// Build the [`ModelParams`] shared by `grcim model` and `grcim query
/// model` from flags (defaults from [`ModelParams::default`]).
fn model_params(args: &Args, model: String) -> Result<ModelParams> {
    let d = ModelParams::default();
    Ok(ModelParams {
        model,
        tokens: args.get_usize("tokens", d.tokens)?,
        arch: args.get_or("arch", d.arch.as_str()).to_string(),
        nr: args.get_usize("nr", d.nr)?,
        nc: args.get_usize("nc", d.nc)?,
        n_e: args.get_f64("ne", d.n_e)?,
        n_m: args.get_f64("nm", d.n_m)?,
        distribution: args.get_or("dist", d.distribution.as_str()).to_string(),
        fit: args.has("fit"),
    })
}

/// `grcim model --model <chain>`: chain tile layers into a full-network
/// energy report (per-layer energy/SQNR, inter-layer requantization,
/// network totals, end-to-end SQNR) and print/persist it. Exits non-zero
/// if an invariant check fails.
fn cmd_model(args: &Args) -> Result<()> {
    args.ensure_known(flags::MODEL)?;
    args.ensure_known_switches(&["fit"])?;
    let model = args
        .get("model")
        .map(String::from)
        .or_else(|| args.positional.first().cloned())
        .context("model needs a chain: grcim model --model mlp:4096x16384x4096")?;
    let spec = model_params(args, model)?.resolve()?;
    let campaign = campaign_from_args(args)?;
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    let t = util::Timer::new("model");
    let res = grcim::model::run_model(&spec, &campaign)?;
    let fr = res.report.to_figure_result();
    let text = fr.emit(&out_dir)?;
    println!("{text}");
    grcim::info!(
        "model done in {:.1}s ({} layers, {} tiles, {:.2} fJ/MAC, e2e {:.1} dB)",
        t.elapsed_s(),
        res.report.layers.len(),
        res.report.tile_count(),
        res.report.fj_per_mac(),
        res.report.sqnr_db
    );
    if !fr.all_hold() {
        bail!("model invariant checks failed (see table above)");
    }
    Ok(())
}

/// `grcim explore --plan <plan.toml>`: expand a Pareto plan into its
/// design-point grid, shard it across the worker pool, and write the
/// campaign output (header line + one JSON record per point, each with
/// its component-level energy breakdown, the digital-IMC baseline, and
/// a `frontier` flag) to `--out`. `--ckpt <path>` makes the run
/// crash-safe: every completed point is fsync'd to the checkpoint
/// before the pool returns, and `grcim explore --resume <path>` adopts
/// the header's plan and engine, skips finished points verbatim, and
/// re-shards only the remainder — the resumed output is bit-identical
/// to an uninterrupted run's.
fn cmd_explore(args: &Args) -> Result<()> {
    use grcim::explore::{self, checkpoint, ParetoPlan};
    args.ensure_known(flags::EXPLORE)?;
    args.ensure_known_switches(&[])?;
    let mut campaign = campaign_from_args(args)?;
    let out = PathBuf::from(args.get_or("out", "results/pareto.jsonl"));
    let t = util::Timer::new("explore");

    let (plan, writer, done) = match args.get("resume") {
        Some(ckpt) => {
            if args.get("plan").is_some() || !args.positional.is_empty() {
                bail!(
                    "--resume takes its plan from the checkpoint header; \
                     drop --plan / the positional plan path"
                );
            }
            let ck = checkpoint::resume(std::path::Path::new(ckpt), None)?;
            // point records are engine-dependent, so resume pins the
            // engine the header recorded, not the CLI default
            campaign.engine = EngineKind::parse(&ck.engine)?;
            grcim::info!(
                "resuming {ckpt}: {}/{} points already done",
                ck.done.len(),
                ck.plan.num_points()
            );
            (ck.plan, Some(ck.writer), ck.done)
        }
        None => {
            let path = args
                .get("plan")
                .map(String::from)
                .or_else(|| args.positional.first().cloned())
                .context("explore needs a plan: grcim explore --plan <plan.toml>")?;
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading plan {path}"))?;
            let mut plan = ParetoPlan::from_toml(&text)?;
            // an explicit --seed overrides the plan's (and therefore
            // its content hash); the plan file's seed wins otherwise
            if args.get("seed").is_some() {
                plan.seed = campaign.seed;
            }
            let engine = explore::engine_name(campaign.engine);
            match args.get("ckpt") {
                Some(ckpt) => {
                    let ck = checkpoint::create(
                        std::path::Path::new(ckpt),
                        &plan,
                        engine,
                    )?;
                    (plan, Some(ck.writer), ck.done)
                }
                None => (plan, None, Default::default()),
            }
        }
    };

    grcim::info!(
        "plan '{}' ({:016x}): {} points on {} workers",
        plan.name,
        plan.content_hash(),
        plan.num_points(),
        campaign.effective_workers()
    );
    let outcome = explore::run_plan(&plan, &campaign, writer, done)?;
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let engine = explore::engine_name(campaign.engine);
    std::fs::write(&out, outcome.out_jsonl(engine))
        .with_context(|| format!("writing {}", out.display()))?;

    let mut tbl = Table::new(
        format!(
            "pareto frontier — plan '{}', {}/{} points non-dominated",
            plan.name,
            outcome.frontier_points().len(),
            outcome.points.len()
        ),
        &[
            "idx", "workload", "nr", "nc", "arch", "fmt", "adc", "fJ/MAC",
            "sqnr (dB)", "vs digital",
        ],
    );
    for p in outcome.frontier_points() {
        tbl.row(vec![
            p.index.to_string(),
            p.workload.clone(),
            p.nr.to_string(),
            p.nc.to_string(),
            p.arch.clone(),
            format!("e{}m{}", p.n_e, p.n_m),
            p.adc.clone(),
            Table::f(p.fj_per_mac),
            Table::f(p.sqnr_db),
            format!("{:.2}x", p.digital_ratio),
        ]);
    }
    println!("{}", tbl.to_markdown());
    grcim::info!(
        "explore done in {:.1}s ({} points -> {})",
        t.elapsed_s(),
        outcome.points.len(),
        out.display()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_validate(_args: &Args) -> Result<()> {
    bail!(
        "validate cross-checks the PJRT backend, which is not compiled in — \
         rebuild with `cargo build --release --features pjrt`"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_validate(args: &Args) -> Result<()> {
    args.ensure_known(flags::VALIDATE)?;
    args.ensure_known_switches(&[])?;
    let dir = artifacts_dir(args);
    let reg = ArtifactRegistry::load(&dir)?;
    let pjrt = grcim::runtime::PjrtEngine::from_registry(&reg)?;
    let rust = grcim::runtime::RustEngine;
    println!("platform: {}", pjrt.platform());
    let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
    let mut worst = 0.0f64;
    for nr in pjrt.depths() {
        use grcim::runtime::Engine as _;
        let batch = pjrt.preferred_batch(nr);
        let mut rng = grcim::rng::Pcg64::seeded(args.get_u64("seed", 7)?);
        let mut x = vec![0.0f32; batch * nr];
        let mut w = vec![0.0f32; batch * nr];
        Distribution::Uniform.fill_f32(&mut rng, &mut x);
        Distribution::clipped_gauss4().fill_f32(&mut rng, &mut w);
        let bp = pjrt.simulate(&x, &w, nr, fmts)?;
        let br = rust.simulate(&x, &w, nr, fmts)?;
        let mut max_diff = 0.0f64;
        for (a, b) in bp.z_q.iter().zip(&br.z_q) {
            max_diff = max_diff.max((a - b).abs());
        }
        worst = worst.max(max_diff);
        println!("nr={nr:<4} batch={batch:<6} max|z_q diff|={max_diff:.3e}");
    }
    if worst > 1e-5 {
        bail!("validation failed: max diff {worst:.3e}");
    }
    println!("validate OK");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.ensure_known(flags::INFO)?;
    args.ensure_known_switches(&[])?;
    let dir = artifacts_dir(args);
    match ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!(
                "artifacts: {} ({} entries)",
                dir.display(),
                reg.entries.len()
            );
            for e in &reg.entries {
                println!(
                    "  {:<24} graph={:<8} nr={:<4} batch={}",
                    e.file, e.graph, e.nr, e.batch
                );
            }
            #[cfg(feature = "pjrt")]
            match grcim::runtime::PjrtEngine::from_registry(&reg) {
                Ok(p) => println!("pjrt: ok ({})", p.platform()),
                Err(e) => println!("pjrt: UNAVAILABLE ({e})"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!("pjrt: not compiled in (build with --features pjrt)");
        }
        Err(e) => println!("artifacts: none ({e}); rust engine only"),
    }
    println!(
        "workers default: {}",
        CampaignConfig::default().effective_workers()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    args.ensure_known(flags::SWEEP)?;
    args.ensure_known_switches(&[])?;
    let path = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.get("config").map(String::from))
        .context("sweep needs a config file: grcim sweep <config.toml>")?;
    let cfg = grcim::config::Config::load(std::path::Path::new(&path))?;
    let plan = SweepPlan::from_config(&cfg)?;
    let aggs = run_campaign(&plan.specs, &plan.campaign)?;
    let mut t = Table::new(
        "sweep results",
        &[
            "experiment", "samples", "enob_conv", "enob_gr_unit",
            "enob_gr_row", "mean_n_eff",
        ],
    );
    let scfg = SpecConfig::default();
    for (spec, agg) in plan.specs.iter().zip(&aggs) {
        t.row(vec![
            spec.id.clone(),
            agg.samples().to_string(),
            Table::f(required_enob(agg, Arch::Conventional, scfg).enob),
            Table::f(required_enob(agg, Arch::GrUnit, scfg).enob),
            Table::f(required_enob(agg, Arch::GrRow, scfg).enob),
            Table::f(agg.mean_n_eff()),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.ensure_known(flags::SERVE)?;
    args.ensure_known_switches(&[])?;
    let server = Server::spawn(ServeConfig {
        addr: args.get_or("addr", DEFAULT_ADDR).to_string(),
        campaign: campaign_from_args(args)?,
        cache_entries: args.get_usize("cache", 1024)?,
        mux_threads: args.get_usize("mux", 0)?,
        compute_threads: args.get_usize("compute", 0)?,
        queue_cap: args.get_usize("queue", 0)?,
        mux_panic_line: None,
    })?;
    println!("grcim serve listening on {}", server.local_addr());
    println!("protocol: one JSON request per line (see docs/CLI.md)");
    server.join()
}

/// Build the request-line mix for `grcim loadgen` from `--mix` (comma
/// list of kinds) or a raw `--json` line, optionally stamping every line
/// with a `--deadline` in milliseconds.
fn loadgen_lines(args: &Args) -> Result<Vec<String>> {
    let samples = args.get_usize("samples", 512)?;
    let mut lines = Vec::new();
    match args.get("json") {
        Some(raw) if raw.trim().is_empty() => {
            bail!("--json needs a non-empty request object")
        }
        Some(raw) => lines.push(raw.to_string()),
        None => {
            for kind in args.get_or("mix", "energy,info").split(',') {
                let kind = kind.trim();
                lines.push(match kind {
                    "" => continue,
                    "info" => r#"{"cmd":"info"}"#.to_string(),
                    "metrics" => r#"{"cmd":"metrics"}"#.to_string(),
                    "energy" => proto::obj(vec![
                        ("cmd", Json::Str("energy".to_string())),
                        ("dr", Json::Num(30.1)),
                        ("sqnr", Json::Num(22.83)),
                        ("samples", Json::Num(samples as f64)),
                    ])
                    .to_string(),
                    "figure" => proto::obj(vec![
                        ("cmd", Json::Str("figure".to_string())),
                        ("id", Json::Str("table1".to_string())),
                        ("samples", Json::Num(256.0)),
                    ])
                    .to_string(),
                    other => bail!(
                        "unknown loadgen mix kind '{other}' \
                         (energy|figure|info|metrics, or --json '<raw request>')"
                    ),
                });
            }
        }
    }
    if let Some(ms) = args.get("deadline") {
        let ms: f64 = ms
            .parse()
            .with_context(|| format!("--deadline expects milliseconds, got '{ms}'"))?;
        for line in lines.iter_mut() {
            let mut j = Json::parse(line).context("--json must be a JSON object")?;
            if let Json::Obj(map) = &mut j {
                map.insert("deadline_ms".to_string(), Json::Num(ms));
            } else {
                bail!("--json must be a JSON object to carry --deadline");
            }
            *line = j.to_string();
        }
    }
    Ok(lines)
}

/// `grcim loadgen`: hold many concurrent connections against a running
/// serve and check byte-identical cached responses under load. Exits
/// non-zero on connect failures, error responses, or response
/// divergence; typed `busy`/`deadline` rejections are tolerated (they
/// are backpressure working as designed) but reported.
fn cmd_loadgen(args: &Args) -> Result<()> {
    args.ensure_known(flags::LOADGEN)?;
    args.ensure_known_switches(&[])?;
    let cfg = grcim::server::loadgen::LoadgenConfig {
        addr: args.get_or("addr", DEFAULT_ADDR).to_string(),
        conns: args.get_usize("conns", 200)?,
        per_conn: args.get_usize("requests", 4)?,
        lines: loadgen_lines(args)?,
        threads: args.get_usize("threads", 0)?,
        loris_ms: args.get_u64("loris-ms", 0)?,
    };
    let report = grcim::server::loadgen::run(&cfg)?;
    println!("{}", report.to_json());
    if !report.clean() {
        bail!(
            "loadgen saw failures: {} connect errors, {} errors, {} divergent responses",
            report.connect_errors,
            report.errors,
            report.divergent
        );
    }
    Ok(())
}

/// `--seed` as a JSON-safe number (JSON carries f64; larger seeds would
/// silently truncate, so they are rejected here like on the server).
fn json_seed(args: &Args) -> Result<Option<f64>> {
    if args.get("seed").is_none() {
        return Ok(None);
    }
    let s = args.get_u64("seed", 0)?;
    if s > proto::MAX_JSON_SEED {
        bail!("--seed must be <= 2^53 for query (JSON numbers are f64)");
    }
    Ok(Some(s as f64))
}

/// Build a request line from `grcim query <kind>` flags (or pass raw JSON
/// through with `--json`).
fn build_request(kind: &str, args: &Args) -> Result<String> {
    match kind {
        "info" => Ok(r#"{"cmd":"info"}"#.to_string()),
        "metrics" => Ok(r#"{"cmd":"metrics"}"#.to_string()),
        "energy" => {
            let mut pairs = vec![
                ("cmd", Json::Str("energy".to_string())),
                ("dr", Json::Num(args.get_f64("dr", 30.1)?)),
                ("sqnr", Json::Num(args.get_f64("sqnr", 22.83)?)),
                (
                    "samples",
                    Json::Num(args.get_usize(
                        "samples",
                        proto::DEFAULT_SAMPLES,
                    )? as f64),
                ),
            ];
            if let Some(s) = json_seed(args)? {
                pairs.push(("seed", Json::Num(s)));
            }
            if let Some(s) = args.get("sampler") {
                // validate client-side so typos fail before the wire
                Sampler::parse(s).map_err(anyhow::Error::msg)?;
                pairs.push(("sampler", Json::Str(s.to_string())));
            }
            Ok(proto::obj(pairs).to_string())
        }
        "figure" => {
            let id = args
                .get("id")
                .map(String::from)
                .or_else(|| args.positional.get(1).cloned())
                .context("figure query needs an id: grcim query figure --id fig9")?;
            let mut pairs = vec![
                ("cmd", Json::Str("figure".to_string())),
                ("id", Json::Str(id)),
                (
                    "samples",
                    Json::Num(args.get_usize(
                        "samples",
                        proto::DEFAULT_FIGURE_SAMPLES,
                    )? as f64),
                ),
            ];
            if let Some(s) = json_seed(args)? {
                pairs.push(("seed", Json::Num(s)));
            }
            Ok(proto::obj(pairs).to_string())
        }
        "workload" => {
            let path = args
                .get("trace")
                .map(String::from)
                .or_else(|| args.positional.get(1).cloned())
                .context(
                    "workload query needs a trace path: \
                     grcim query workload --trace <file> (a relative path, \
                     resolved in the server's working directory)",
                )?;
            let mut pairs = vec![
                ("cmd", Json::Str("workload".to_string())),
                ("path", Json::Str(path)),
                (
                    "samples",
                    Json::Num(args.get_usize(
                        "samples",
                        proto::DEFAULT_FIGURE_SAMPLES,
                    )? as f64),
                ),
            ];
            if let Some(s) = json_seed(args)? {
                pairs.push(("seed", Json::Num(s)));
            }
            Ok(proto::obj(pairs).to_string())
        }
        "layer" => {
            let shape = args
                .get("shape")
                .map(String::from)
                .or_else(|| args.positional.get(1).cloned())
                .context(
                    "layer query needs a shape: \
                     grcim query layer --shape mlp-up:4096",
                )?;
            let p = layer_params(args, shape)?;
            let mut pairs = vec![
                ("cmd", Json::Str("layer".to_string())),
                ("shape", Json::Str(p.shape)),
                ("tokens", Json::Num(p.tokens as f64)),
                ("arch", Json::Str(p.arch)),
                ("nr", Json::Num(p.nr as f64)),
                ("nc", Json::Num(p.nc as f64)),
                ("n_e", Json::Num(p.n_e)),
                ("n_m", Json::Num(p.n_m)),
                ("distribution", Json::Str(p.distribution)),
            ];
            if let Some(s) = json_seed(args)? {
                pairs.push(("seed", Json::Num(s)));
            }
            Ok(proto::obj(pairs).to_string())
        }
        "model" => {
            let model = args
                .get("model")
                .map(String::from)
                .or_else(|| args.positional.get(1).cloned())
                .context(
                    "model query needs a chain: \
                     grcim query model --model mlp:4096x16384x4096",
                )?;
            let p = model_params(args, model)?;
            let mut pairs = vec![
                ("cmd", Json::Str("model".to_string())),
                ("model", Json::Str(p.model)),
                ("tokens", Json::Num(p.tokens as f64)),
                ("arch", Json::Str(p.arch)),
                ("nr", Json::Num(p.nr as f64)),
                ("nc", Json::Num(p.nc as f64)),
                ("n_e", Json::Num(p.n_e)),
                ("n_m", Json::Num(p.n_m)),
                ("distribution", Json::Str(p.distribution)),
                ("fit", Json::Bool(p.fit)),
            ];
            if let Some(s) = json_seed(args)? {
                pairs.push(("seed", Json::Num(s)));
            }
            Ok(proto::obj(pairs).to_string())
        }
        "sweep" => {
            let path = args.positional.get(1).context(
                "sweep query needs a config: grcim query sweep <config.toml>",
            )?;
            let cfg = grcim::config::Config::load(std::path::Path::new(path))?;
            let mut exps = Vec::new();
            for exp in cfg.sections_named("experiment") {
                let mut pairs = Vec::new();
                if let Some(name) = exp.get("name").and_then(|v| v.as_str()) {
                    pairs.push(("name", Json::Str(name.to_string())));
                }
                for key in ["n_e", "n_m", "nr"] {
                    if let Some(n) = exp.get(key).and_then(|v| v.as_f64()) {
                        pairs.push((key, Json::Num(n)));
                    }
                }
                if let Some(d) =
                    exp.get("distribution").and_then(|v| v.as_str())
                {
                    pairs.push(("distribution", Json::Str(d.to_string())));
                }
                exps.push(proto::obj(pairs));
            }
            let mut pairs = vec![
                ("cmd", Json::Str("sweep".to_string())),
                ("experiments", Json::Arr(exps)),
            ];
            // flag overrides config, config overrides the server default
            if let Some(n) = args
                .get("samples")
                .map(|_| args.get_usize("samples", 0))
                .transpose()?
                .or_else(|| cfg.root.get("samples").and_then(|v| v.as_usize()))
            {
                pairs.push(("samples", Json::Num(n as f64)));
            }
            if let Some(s) = json_seed(args)? {
                pairs.push(("seed", Json::Num(s)));
            } else if let Some(s) =
                cfg.root.get("seed").and_then(|v| v.as_f64())
            {
                pairs.push(("seed", Json::Num(s)));
            }
            if let Some(s) = args
                .get("sampler")
                .or_else(|| cfg.root.get("sampler").and_then(|v| v.as_str()))
            {
                Sampler::parse(s).map_err(anyhow::Error::msg)?;
                pairs.push(("sampler", Json::Str(s.to_string())));
            }
            Ok(proto::obj(pairs).to_string())
        }
        "pareto" => {
            let path = args
                .get("plan")
                .map(String::from)
                .or_else(|| args.positional.get(1).cloned())
                .context(
                    "pareto query needs a plan: \
                     grcim query pareto --plan <plan.toml>",
                )?;
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading plan {path}"))?;
            // validate client-side so a bad plan fails before the wire
            grcim::explore::ParetoPlan::from_toml(&text)?;
            Ok(proto::obj(vec![
                ("cmd", Json::Str("pareto".to_string())),
                ("plan", Json::Str(text)),
            ])
            .to_string())
        }
        other => bail!(
            "unknown query kind '{other}' \
             (energy|sweep|figure|workload|layer|model|pareto|metrics|info, \
             or --json '<raw request>')"
        ),
    }
}

fn cmd_query(args: &Args) -> Result<()> {
    args.ensure_known(flags::QUERY)?;
    args.ensure_known_switches(&["fit"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let line = match args.get("json") {
        // the server ignores blank lines, so an empty request would hang
        // the client waiting for a response that never comes
        Some(raw) if raw.trim().is_empty() => {
            bail!("--json needs a non-empty request object")
        }
        Some(raw) => raw.to_string(),
        None => {
            let kind = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("info");
            build_request(kind, args)?
        }
    };
    let resp = grcim::server::query_once(addr, &line)?;
    println!("{resp}");
    let j = Json::parse(&resp).context("server sent malformed JSON")?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        bail!(
            "server error: {}",
            j.get("error").and_then(Json::as_str).unwrap_or("unknown")
        );
    }
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        util::set_level(Level::Debug);
    } else if args.has("quiet") {
        util::set_level(Level::Error);
    }
    if args.command.is_empty() || args.has("help") {
        println!("{USAGE}");
        return;
    }
    let result = match args.command.as_str() {
        "figures" => cmd_figures(&args),
        "energy" => cmd_energy(&args),
        "workload" => cmd_workload(&args),
        "layer" => cmd_layer(&args),
        "model" => cmd_model(&args),
        "explore" => cmd_explore(&args),
        "validate" => cmd_validate(&args),
        "info" => cmd_info(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        "loadgen" => cmd_loadgen(&args),
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
