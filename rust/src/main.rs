//! `grcim` — CLI launcher for the GR-CIM design-space exploration
//! framework.
//!
//! Subcommands:
//!   figures   regenerate paper tables/figures (--fig all|fig4|...|table1)
//!   energy    query the energy model at one (DR, SQNR) spec point
//!   validate  cross-check the PJRT artifacts against the Rust oracle
//!             (needs a build with `--features pjrt`)
//!   info      show artifact registry + engine status
//!   sweep     run a campaign described by a TOML config
//!
//! Common flags: --engine rust|pjrt|auto, --artifacts DIR, --out DIR,
//! --samples N, --seed N, --workers N, --quick, --verbose, --quiet.
//!
//! The default build is self-contained: every command runs on the pure-
//! Rust oracle with no artifacts present (`--engine auto` falls back).

use anyhow::{bail, Context, Result};
use grcim::cli::Args;
use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::figures::{FigureCtx, ALL};
use grcim::formats::FpFormat;
use grcim::mac::FormatPair;
use grcim::report::Table;
use grcim::runtime::{ArtifactRegistry, EngineKind};
use grcim::spec::{required_enob, Arch, SpecConfig};
use grcim::util::{self, Level};
use std::path::PathBuf;

const USAGE: &str = "\
grcim — Gain-Ranging CIM design-space exploration (paper reproduction)

USAGE: grcim <command> [flags]

COMMANDS:
  figures    regenerate paper figures/tables
             --fig all|fig4|table1|fig8|fig9|fig10|fig11|fig12|ablations
             --out results --samples 65536 --quick
  energy     energy model at a spec point: --dr <dB> --sqnr <dB>
  validate   PJRT artifacts vs the pure-Rust oracle (--features pjrt builds)
  sweep      run a TOML campaign: grcim sweep <config.toml>
  info       artifact + engine status

COMMON FLAGS:
  --engine rust|pjrt|auto   backend (default auto)
  --artifacts DIR           artifact directory (default ./artifacts)
  --workers N               worker threads (default: cores)
  --seed N                  campaign seed
  --verbose / --quiet       log level
";

fn campaign_from_args(args: &Args) -> Result<CampaignConfig> {
    Ok(CampaignConfig {
        engine: EngineKind::parse(args.get_or("engine", "auto"))?,
        artifacts_dir: PathBuf::from(args.get_or(
            "artifacts",
            ArtifactRegistry::default_dir().to_str().unwrap_or("artifacts"),
        )),
        workers: args.get_usize("workers", 0)?,
        seed: args.get_u64("seed", 0xC1A0_57A7)?,
    })
}

fn cmd_figures(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "fig", "out", "samples", "engine", "artifacts", "workers", "seed",
    ])?;
    let mut ctx = FigureCtx {
        campaign: campaign_from_args(args)?,
        samples: args.get_usize("samples", 65_536)?,
        out_dir: PathBuf::from(args.get_or("out", "results")),
    };
    if args.has("quick") {
        ctx = ctx.quick();
    }
    let which = args.get_or("fig", "all");
    let ids: Vec<&str> = if which == "all" {
        ALL.to_vec()
    } else {
        which.split(',').collect()
    };
    let mut failed = Vec::new();
    for id in ids {
        let t = util::Timer::new(format!("figure {id}"));
        let fr = grcim::figures::run(id, &ctx)?;
        let text = fr.emit(&ctx.out_dir)?;
        println!("{text}");
        grcim::info!("{id} done in {:.1}s", t.elapsed_s());
        if !fr.all_hold() {
            failed.push(id.to_string());
        }
    }
    if !failed.is_empty() {
        bail!("paper-shape checks failed for: {}", failed.join(", "));
    }
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "dr", "sqnr", "samples", "engine", "artifacts", "workers", "seed",
    ])?;
    let dr = args.get_f64("dr", 30.1)?;
    let sqnr = args.get_f64("sqnr", 22.83)?;
    let ctx = FigureCtx {
        campaign: campaign_from_args(args)?,
        samples: args.get_usize("samples", 16_384)?,
        out_dir: PathBuf::from("results"),
    };
    let p = grcim::figures::fig12::SpecPoint {
        dr_bits: dr / 6.02,
        n_m_eff: (sqnr - 10.79) / 6.02,
    };
    let tech = grcim::energy::TechParams::default();
    let res =
        grcim::figures::fig12::evaluate_points(&ctx, &[p], ctx.samples, &tech)?;
    let Some(r) = &res[0] else {
        bail!("spec point (DR {dr} dB, SQNR {sqnr} dB) is left of the INT line");
    };
    let mut t = Table::new(
        format!("energy @ DR={dr} dB, SQNR={sqnr} dB"),
        &["arch", "enob", "fJ/op", "adc", "dac", "cells", "logic+tree+mult"],
    );
    t.row(vec![
        "conventional".into(),
        Table::f(r.enob_conv),
        Table::f(r.e_conv.total()),
        Table::f(r.e_conv.adc),
        Table::f(r.e_conv.dac),
        Table::f(r.e_conv.cells),
        Table::f(r.e_conv.exp_logic + r.e_conv.tree + r.e_conv.norm_mult),
    ]);
    for (arch, enob, b) in &r.gr_all {
        t.row(vec![
            arch.name().into(),
            Table::f(*enob),
            Table::f(b.total()),
            Table::f(b.adc),
            Table::f(b.dac),
            Table::f(b.cells),
            Table::f(b.exp_logic + b.tree + b.norm_mult),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_validate(_args: &Args) -> Result<()> {
    bail!(
        "validate cross-checks the PJRT backend, which is not compiled in — \
         rebuild with `cargo build --release --features pjrt`"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_validate(args: &Args) -> Result<()> {
    args.ensure_known(&["artifacts", "samples", "seed"])?;
    let dir = PathBuf::from(args.get_or(
        "artifacts",
        ArtifactRegistry::default_dir().to_str().unwrap_or("artifacts"),
    ));
    let reg = ArtifactRegistry::load(&dir)?;
    let pjrt = grcim::runtime::PjrtEngine::from_registry(&reg)?;
    let rust = grcim::runtime::RustEngine;
    println!("platform: {}", pjrt.platform());
    let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
    let mut worst = 0.0f64;
    for nr in pjrt.depths() {
        use grcim::runtime::Engine as _;
        let batch = pjrt.preferred_batch(nr);
        let mut rng = grcim::rng::Pcg64::seeded(args.get_u64("seed", 7)?);
        let mut x = vec![0.0f32; batch * nr];
        let mut w = vec![0.0f32; batch * nr];
        Distribution::Uniform.fill_f32(&mut rng, &mut x);
        Distribution::clipped_gauss4().fill_f32(&mut rng, &mut w);
        let bp = pjrt.simulate(&x, &w, nr, fmts)?;
        let br = rust.simulate(&x, &w, nr, fmts)?;
        let mut max_diff = 0.0f64;
        for (a, b) in bp.z_q.iter().zip(&br.z_q) {
            max_diff = max_diff.max((a - b).abs());
        }
        worst = worst.max(max_diff);
        println!("nr={nr:<4} batch={batch:<6} max|z_q diff|={max_diff:.3e}");
    }
    if worst > 1e-5 {
        bail!("validation failed: max diff {worst:.3e}");
    }
    println!("validate OK");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or(
        "artifacts",
        ArtifactRegistry::default_dir().to_str().unwrap_or("artifacts"),
    ));
    match ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!(
                "artifacts: {} ({} entries)",
                dir.display(),
                reg.entries.len()
            );
            for e in &reg.entries {
                println!(
                    "  {:<24} graph={:<8} nr={:<4} batch={}",
                    e.file, e.graph, e.nr, e.batch
                );
            }
            #[cfg(feature = "pjrt")]
            match grcim::runtime::PjrtEngine::from_registry(&reg) {
                Ok(p) => println!("pjrt: ok ({})", p.platform()),
                Err(e) => println!("pjrt: UNAVAILABLE ({e})"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!("pjrt: not compiled in (build with --features pjrt)");
        }
        Err(e) => println!("artifacts: none ({e}); rust engine only"),
    }
    println!(
        "workers default: {}",
        CampaignConfig::default().effective_workers()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.get("config").map(String::from))
        .context("sweep needs a config file: grcim sweep <config.toml>")?;
    let cfg = grcim::config::Config::load(std::path::Path::new(&path))?;
    let mut campaign = CampaignConfig::default();
    if let Some(seed) = cfg.root.get("seed").and_then(|v| v.as_f64()) {
        campaign.seed = seed as u64;
    }
    if let Some(engine) = cfg
        .section("engine")
        .and_then(|t| t.get("kind"))
        .and_then(|v| v.as_str())
    {
        campaign.engine = EngineKind::parse(engine)?;
    }
    let samples = cfg
        .root
        .get("samples")
        .and_then(|v| v.as_usize())
        .unwrap_or(16_384);

    let mut specs = Vec::new();
    for exp in cfg.sections_named("experiment") {
        let name = exp
            .get("name")
            .and_then(|v| v.as_str())
            .context("experiment needs a name")?;
        let n_e = exp.get("n_e").and_then(|v| v.as_f64()).unwrap_or(2.0);
        let n_m = exp.get("n_m").and_then(|v| v.as_f64()).unwrap_or(2.0);
        let nr = exp.get("nr").and_then(|v| v.as_usize()).unwrap_or(32);
        let dist = exp
            .get("distribution")
            .and_then(|v| v.as_str())
            .unwrap_or("uniform");
        let fmt = FpFormat::fp(n_e as u32, n_m as u32);
        let dist_x = match dist {
            "uniform" => Distribution::Uniform,
            "max_entropy" => Distribution::max_entropy(fmt),
            "gauss_outliers" => Distribution::gauss_outliers(),
            "clipped_gauss" => Distribution::clipped_gauss4(),
            other => bail!("unknown distribution '{other}'"),
        };
        specs.push(ExperimentSpec {
            id: name.to_string(),
            fmts: FormatPair::new(fmt, FpFormat::fp4_e2m1()),
            dist_x,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr,
            samples,
        });
    }
    if specs.is_empty() {
        bail!("config has no [[experiment]] sections");
    }
    let aggs = run_campaign(&specs, &campaign)?;
    let mut t = Table::new(
        "sweep results",
        &[
            "experiment", "samples", "enob_conv", "enob_gr_unit",
            "enob_gr_row", "mean_n_eff",
        ],
    );
    let scfg = SpecConfig::default();
    for (spec, agg) in specs.iter().zip(&aggs) {
        t.row(vec![
            spec.id.clone(),
            agg.samples().to_string(),
            Table::f(required_enob(agg, Arch::Conventional, scfg).enob),
            Table::f(required_enob(agg, Arch::GrUnit, scfg).enob),
            Table::f(required_enob(agg, Arch::GrRow, scfg).enob),
            Table::f(agg.mean_n_eff()),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        util::set_level(Level::Debug);
    } else if args.has("quiet") {
        util::set_level(Level::Error);
    }
    if args.command.is_empty() || args.has("help") {
        println!("{USAGE}");
        return;
    }
    let result = match args.command.as_str() {
        "figures" => cmd_figures(&args),
        "energy" => cmd_energy(&args),
        "validate" => cmd_validate(&args),
        "info" => cmd_info(&args),
        "sweep" => cmd_sweep(&args),
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
