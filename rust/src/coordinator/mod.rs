//! L3 coordinator: the simulation-campaign manager.
//!
//! A *campaign* is a grid of [`ExperimentSpec`]s (format x distribution x
//! array depth), each requiring a number of Monte-Carlo samples. The
//! coordinator splits every experiment into engine-sized batch jobs,
//! schedules them over a worker pool (each worker owns its backend — PJRT
//! wrapper types are not `Send`, so engines are built per-thread through
//! [`crate::runtime::build_engine`]), streams per-job aggregates back, and
//! merges them into one [`ColumnAgg`] per experiment.
//!
//! Determinism: job RNG streams are `Pcg64::seeded(job_seed(campaign_seed,
//! spec_index, batch_index))`, so results are independent of worker count
//! and scheduling order (verified in `pool_order_independence`).

pub mod pool;

use crate::distributions::{Distribution, Sampler};
use crate::mac::FormatPair;
use crate::rng::{job_seed, Pcg64};
use crate::runtime::{build_engine, Engine, EngineKind, SimScratch};
use crate::stats::{ColumnAgg, ColumnBatch};
use anyhow::{Context, Result};
use std::path::PathBuf;
use crate::util::sync::Arc;

/// One grid point of a campaign.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Stable identifier (participates in reports, not in seeding).
    pub id: String,
    /// Input/weight format pair.
    pub fmts: FormatPair,
    /// Input (activation) workload distribution.
    pub dist_x: Distribution,
    /// Weight workload distribution.
    pub dist_w: Distribution,
    /// Array depth (accumulation length).
    pub nr: usize,
    /// Requested Monte-Carlo samples (rounded up to whole engine batches).
    pub samples: usize,
    /// Monte-Carlo estimator mode ([`Sampler::Plain`] is the historical,
    /// bit-pinned default; the variance-reduced modes are opt-in).
    pub sampler: Sampler,
}

/// Campaign-wide settings.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Which backend workers build.
    pub engine: EngineKind,
    /// AOT artifact directory (PJRT builds).
    pub artifacts_dir: PathBuf,
    /// Worker threads; 0 = available_parallelism.
    pub workers: usize,
    /// Campaign seed (job streams derive from it via `rng::job_seed`).
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            engine: EngineKind::Rust,
            artifacts_dir: crate::runtime::ArtifactRegistry::default_dir(),
            workers: 0,
            seed: 0xC1A0_57A7,
        }
    }
}

impl CampaignConfig {
    /// The worker count actually used (resolves 0 to the host's
    /// available parallelism).
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        }
    }
}

/// Reusable per-worker buffers for the allocation-free job path: the f32
/// input slabs, the engine's widening scratch, and one [`ColumnBatch`]
/// that every chunk is simulated into. After the first job at a given
/// shape, running further jobs performs no heap allocation in the hot loop
/// (verified by `cargo bench --bench hotpath`).
#[derive(Debug, Default)]
pub struct JobBuffers {
    x: Vec<f32>,
    w: Vec<f32>,
    scratch: SimScratch,
    batch: ColumnBatch,
}

/// Generate one job's inputs into `bufs` and stream it through the engine
/// in chunks of the engine's preferred batch, merging the per-sample
/// statistics into one [`ColumnAgg`].
///
/// Results are bit-identical to [`run_job`] for any chunking: the RNG
/// fills the whole job's `x` then `w` up front (the seeding contract), and
/// aggregation is per-sample in order, so chunk boundaries are invisible.
pub fn run_job_buffered(
    engine: &dyn Engine,
    spec: &ExperimentSpec,
    campaign_seed: u64,
    spec_idx: u64,
    batch_idx: u64,
    batch_samples: usize,
    bufs: &mut JobBuffers,
) -> Result<ColumnAgg> {
    let mut rng = Pcg64::seeded(job_seed(campaign_seed, spec_idx, batch_idx));
    let n = batch_samples * spec.nr;
    bufs.x.resize(n, 0.0);
    bufs.w.resize(n, 0.0);
    // the sampler consumes the same job stream for both slabs, so a job
    // stays a pure function of its seed in every estimator mode (Plain
    // delegates to the bit-identical sequential fill)
    spec.sampler.fill_slab_f32(&spec.dist_x, &mut rng, &mut bufs.x, spec.nr);
    spec.sampler.fill_slab_f32(&spec.dist_w, &mut rng, &mut bufs.w, spec.nr);
    let mut agg = ColumnAgg::new(spec.nr);
    let chunk = engine.preferred_batch(spec.nr).max(1) * spec.nr;
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + chunk).min(n);
        engine
            .simulate_into(
                &bufs.x[lo..hi],
                &bufs.w[lo..hi],
                spec.nr,
                spec.fmts,
                &mut bufs.scratch,
                &mut bufs.batch,
            )
            .with_context(|| format!("job {}/{batch_idx}", spec.id))?;
        agg.push_batch(&bufs.batch);
        lo = hi;
    }
    Ok(agg)
}

/// Generate one job's inputs and run it on an engine (allocating
/// convenience wrapper over [`run_job_buffered`]).
pub fn run_job(
    engine: &dyn Engine,
    spec: &ExperimentSpec,
    campaign_seed: u64,
    spec_idx: u64,
    batch_idx: u64,
    batch_samples: usize,
) -> Result<ColumnAgg> {
    let mut bufs = JobBuffers::default();
    run_job_buffered(
        engine,
        spec,
        campaign_seed,
        spec_idx,
        batch_idx,
        batch_samples,
        &mut bufs,
    )
}

/// Run a whole experiment on one engine (single-threaded convenience used
/// by tests and small figures). Buffers are reused across the experiment's
/// jobs.
pub fn run_experiment(
    engine: &dyn Engine,
    spec: &ExperimentSpec,
    campaign_seed: u64,
) -> Result<ColumnAgg> {
    let batch = engine.preferred_batch(spec.nr);
    let jobs = spec.samples.div_ceil(batch);
    let mut agg = ColumnAgg::new(spec.nr);
    let mut bufs = JobBuffers::default();
    for j in 0..jobs {
        agg.merge(&run_job_buffered(
            engine,
            spec,
            campaign_seed,
            0,
            j as u64,
            batch,
            &mut bufs,
        )?);
    }
    Ok(agg)
}

/// Run a campaign grid across the worker pool; returns one aggregate per
/// spec, in input order.
pub fn run_campaign(
    specs: &[ExperimentSpec],
    cfg: &CampaignConfig,
) -> Result<Vec<ColumnAgg>> {
    if specs.is_empty() {
        return Ok(Vec::new());
    }
    let specs: Arc<Vec<ExperimentSpec>> = Arc::new(specs.to_vec());

    // plan jobs: (spec_idx, batch_idx, batch_samples)
    // batch sizing must not depend on which engine a worker builds, so we
    // use the canonical artifact batch (2048) — both engines accept it.
    const JOB_BATCH: usize = 2048;
    let mut jobs = Vec::new();
    for (si, spec) in specs.iter().enumerate() {
        let n_jobs = spec.samples.div_ceil(JOB_BATCH);
        for bi in 0..n_jobs {
            jobs.push(pool::Job { spec_idx: si, batch_idx: bi as u64 });
        }
    }

    let seed = cfg.seed;
    let engine_kind = cfg.engine;
    let artifacts = cfg.artifacts_dir.clone();
    let specs_for_worker = Arc::clone(&specs);

    let results = pool::run_jobs(
        jobs,
        cfg.effective_workers(),
        move || {
            let engine = build_engine(engine_kind, &artifacts)?;
            let specs = Arc::clone(&specs_for_worker);
            // per-worker reusable buffers: every job this worker pulls is
            // chunked through the same slabs + ColumnBatch, so the hot
            // loop is allocation-free after the first job
            let mut bufs = JobBuffers::default();
            Ok(move |job: pool::Job| -> Result<(usize, ColumnAgg)> {
                let spec = &specs[job.spec_idx];
                let agg = run_job_buffered(
                    engine.as_ref(),
                    spec,
                    seed,
                    job.spec_idx as u64,
                    job.batch_idx,
                    JOB_BATCH,
                    &mut bufs,
                )?;
                Ok((job.spec_idx, agg))
            })
        },
    )?;

    // merge per spec
    let mut aggs: Vec<ColumnAgg> =
        specs.iter().map(|s| ColumnAgg::new(s.nr)).collect();
    for (spec_idx, agg) in results {
        aggs[spec_idx].merge(&agg);
    }
    Ok(aggs)
}

/// Pilot jobs per estimator mode in [`samples_for_ci`].
pub const CI_PILOT_JOBS: u64 = 8;
/// Samples per pilot job in [`samples_for_ci`] (the canonical job batch).
pub const CI_PILOT_SAMPLES: usize = 2048;
/// Two-sided 95% normal quantile used for the CI half-width.
pub const CI_Z: f64 = 1.96;

/// Samples-for-equal-CI estimate of one estimator mode.
#[derive(Debug, Clone, Copy)]
pub struct CiEstimate {
    /// The estimator mode measured.
    pub sampler: Sampler,
    /// Mean per-pilot-job SQNR estimate (dB) at [`CI_PILOT_SAMPLES`].
    pub sqnr_db_mean: f64,
    /// Sample standard deviation of the per-job SQNR estimates (dB).
    pub sqnr_db_std: f64,
    /// Samples needed for a 95% CI half-width of the requested dB.
    pub required_samples: u64,
}

/// The `--target-ci` knob: how many Monte-Carlo samples each estimator
/// mode needs for the campaign's SQNR estimate to reach a 95% confidence
/// half-width of `half_width_db` dB.
///
/// Runs [`CI_PILOT_JOBS`] pilot jobs of [`CI_PILOT_SAMPLES`] samples per
/// mode (standard job seeding, batch indices 0..K), takes the sample
/// variance of the per-job SQNR estimates, and scales: the estimate from
/// `n` samples has variance ≈ σ²·n₀/n, so
/// `n = ceil(z²·σ²·n₀ / h²)`. Fully deterministic at a fixed seed — the
/// counts are golden-pinned and cross-checked against the Python twin
/// (`tools/gen_goldens.py`).
pub fn samples_for_ci(
    engine: &dyn Engine,
    spec: &ExperimentSpec,
    seed: u64,
    half_width_db: f64,
) -> Result<Vec<CiEstimate>> {
    assert!(half_width_db > 0.0, "CI half-width must be positive");
    let mut out = Vec::with_capacity(Sampler::ALL.len());
    let mut bufs = JobBuffers::default();
    for sampler in Sampler::ALL {
        let mut s = spec.clone();
        s.sampler = sampler;
        let mut sqnrs = [0.0f64; CI_PILOT_JOBS as usize];
        for (j, v) in sqnrs.iter_mut().enumerate() {
            let agg = run_job_buffered(
                engine,
                &s,
                seed,
                0,
                j as u64,
                CI_PILOT_SAMPLES,
                &mut bufs,
            )?;
            *v = agg.sqnr_db();
        }
        let k = CI_PILOT_JOBS as f64;
        let mean = sqnrs.iter().sum::<f64>() / k;
        let var = sqnrs.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / (k - 1.0);
        let required = (CI_Z * CI_Z * var * CI_PILOT_SAMPLES as f64
            / (half_width_db * half_width_db))
            .ceil()
            .max(1.0) as u64;
        out.push(CiEstimate {
            sampler,
            sqnr_db_mean: mean,
            sqnr_db_std: var.sqrt(),
            required_samples: required,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;
    use crate::runtime::RustEngine;

    fn spec(samples: usize) -> ExperimentSpec {
        ExperimentSpec {
            id: "t".into(),
            fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples,
            sampler: Sampler::Plain,
        }
    }

    #[test]
    fn run_job_deterministic() {
        let e = RustEngine;
        let a = run_job(&e, &spec(64), 7, 0, 0, 64).unwrap();
        let b = run_job(&e, &spec(64), 7, 0, 0, 64).unwrap();
        assert_eq!(a.nf.sum.to_bits(), b.nf.sum.to_bits());
        // different batch index -> different stream
        let c = run_job(&e, &spec(64), 7, 0, 1, 64).unwrap();
        assert_ne!(a.nf.sum.to_bits(), c.nf.sum.to_bits());
    }

    #[test]
    fn run_experiment_rounds_up_to_batches() {
        let e = RustEngine;
        let agg = run_experiment(&e, &spec(3000), 1).unwrap();
        // rounded up to 2 x 2048
        assert_eq!(agg.samples(), 4096);
    }

    #[test]
    fn campaign_matches_single_threaded() {
        let specs = vec![spec(4096), {
            let mut s = spec(2048);
            s.id = "t2".into();
            s.dist_x = Distribution::clipped_gauss4();
            s
        }];
        let cfg = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 4,
            seed: 99,
            ..Default::default()
        };
        let par = run_campaign(&specs, &cfg).unwrap();

        // single-threaded reference with the same seeding scheme
        let e = RustEngine;
        for (si, spec) in specs.iter().enumerate() {
            let jobs = spec.samples.div_ceil(2048);
            let mut agg = ColumnAgg::new(spec.nr);
            for bi in 0..jobs {
                agg.merge(
                    &run_job(&e, spec, 99, si as u64, bi as u64, 2048).unwrap(),
                );
            }
            assert_eq!(par[si].samples(), agg.samples());
            assert_eq!(par[si].nf.sum.to_bits(), agg.nf.sum.to_bits());
            assert_eq!(par[si].sig.sum_sq.to_bits(), agg.sig.sum_sq.to_bits());
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let specs = vec![spec(6144)];
        let mut aggs = Vec::new();
        for workers in [1, 3, 8] {
            let cfg = CampaignConfig {
                engine: EngineKind::Rust,
                workers,
                seed: 5,
                ..Default::default()
            };
            aggs.push(run_campaign(&specs, &cfg).unwrap());
        }
        for pair in aggs.windows(2) {
            assert_eq!(
                pair[0][0].nf.sum.to_bits(),
                pair[1][0].nf.sum.to_bits()
            );
        }
    }

    #[test]
    fn empty_campaign_is_fine() {
        let cfg = CampaignConfig::default();
        assert!(run_campaign(&[], &cfg).unwrap().is_empty());
    }

    #[test]
    fn buffered_jobs_reuse_is_bit_identical() {
        let e = RustEngine;
        let mut bufs = JobBuffers::default();
        // run two different shapes through the same buffers; each must
        // match a fresh allocating run exactly
        let s32 = spec(256);
        let mut s8 = spec(128);
        s8.nr = 8;
        for (sp, bi) in [(&s32, 0u64), (&s8, 1), (&s32, 2)] {
            let reused =
                run_job_buffered(&e, sp, 11, 0, bi, 128, &mut bufs).unwrap();
            let fresh = run_job(&e, sp, 11, 0, bi, 128).unwrap();
            assert_eq!(reused.samples(), fresh.samples());
            assert_eq!(reused.nf.sum.to_bits(), fresh.nf.sum.to_bits());
            assert_eq!(reused.sig.sum_sq.to_bits(), fresh.sig.sum_sq.to_bits());
            assert_eq!(
                reused.n_eff.sum.to_bits(),
                fresh.n_eff.sum.to_bits()
            );
        }
    }

    #[test]
    fn chunking_does_not_change_job_results() {
        // a job larger than the engine's preferred batch is split into
        // chunks internally; the aggregate must not depend on that split
        struct SmallBatch;
        impl crate::runtime::Engine for SmallBatch {
            fn simulate(
                &self,
                x: &[f32],
                w: &[f32],
                nr: usize,
                fmts: crate::mac::FormatPair,
            ) -> anyhow::Result<crate::stats::ColumnBatch> {
                RustEngine.simulate(x, w, nr, fmts)
            }
            fn preferred_batch(&self, _nr: usize) -> usize {
                7 // force many ragged-looking chunks
            }
            fn supports_nr(&self, _nr: usize) -> bool {
                true
            }
            fn name(&self) -> &'static str {
                "small"
            }
        }
        let sp = spec(64);
        let whole = run_job(&RustEngine, &sp, 3, 0, 0, 64).unwrap();
        let chunked = run_job(&SmallBatch, &sp, 3, 0, 0, 64).unwrap();
        assert_eq!(whole.samples(), chunked.samples());
        assert_eq!(whole.nf.sum.to_bits(), chunked.nf.sum.to_bits());
        assert_eq!(whole.qerr.sum_sq.to_bits(), chunked.qerr.sum_sq.to_bits());
    }

    /// The acceptance-criteria spec point: an FP8-class input format whose
    /// SQNR sits near 35 dB under the clipped-Gaussian activation model
    /// (Fig. 4). The smooth, symmetric quantile map is what the
    /// variance-reduced modes exploit; the Gaussian+outliers mixture is
    /// deliberately NOT used here — its SQNR noise is dominated by the
    /// outlier magnitudes themselves, which neither pairing nor
    /// stratification controls (measured: no reduction), see
    /// docs/THEORY.md.
    fn ci_spec() -> ExperimentSpec {
        ExperimentSpec {
            id: "ci35".into(),
            fmts: FormatPair::new(FpFormat::fp(4, 3), FpFormat::fp4_e2m1()),
            dist_x: Distribution::clipped_gauss4(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: CI_PILOT_SAMPLES,
            sampler: Sampler::Plain,
        }
    }

    #[test]
    fn variance_reduction_beats_plain_by_2x_at_the_35db_point() {
        let est =
            samples_for_ci(&RustEngine, &ci_spec(), 0xC1, 0.25).unwrap();
        assert_eq!(est.len(), 3);
        let by = |s: Sampler| {
            est.iter().find(|e| e.sampler == s).unwrap().required_samples
        };
        let plain = by(Sampler::Plain);
        let best = by(Sampler::Antithetic).min(by(Sampler::Stratified));
        // the SQNR estimate sits near 35 dB and at least one
        // variance-reduced mode needs >= 2x fewer samples for the same CI
        let mean =
            est.iter().find(|e| e.sampler == Sampler::Plain).unwrap().sqnr_db_mean;
        assert!((30.0..40.0).contains(&mean), "sqnr mean {mean}");
        assert!(
            plain >= 2 * best,
            "plain {plain} vs best variance-reduced {best}"
        );
    }

    #[test]
    fn samples_for_ci_is_deterministic_and_scales_with_half_width() {
        let a = samples_for_ci(&RustEngine, &ci_spec(), 7, 0.5).unwrap();
        let b = samples_for_ci(&RustEngine, &ci_spec(), 7, 0.5).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.required_samples, y.required_samples);
            assert_eq!(x.sqnr_db_mean.to_bits(), y.sqnr_db_mean.to_bits());
        }
        // halving the half-width quadruples the required samples (up to
        // the ceil)
        let tight = samples_for_ci(&RustEngine, &ci_spec(), 7, 0.25).unwrap();
        for (w, t) in a.iter().zip(tight.iter()) {
            assert!(
                t.required_samples >= 3 * w.required_samples,
                "{:?}: {} vs {}",
                w.sampler,
                w.required_samples,
                t.required_samples
            );
        }
    }

    #[test]
    fn sampler_modes_preserve_the_estimate_within_mc_tolerance() {
        // all three estimators target the same quantity; their pooled
        // SQNR estimates must agree to Monte-Carlo noise
        let e = RustEngine;
        let mut sqnr = Vec::new();
        for sampler in Sampler::ALL {
            let mut s = ci_spec();
            s.sampler = sampler;
            s.samples = 8192;
            let agg = run_experiment(&e, &s, 0xE5).unwrap();
            sqnr.push(agg.sqnr_db());
        }
        for v in &sqnr[1..] {
            assert!(
                (v - sqnr[0]).abs() < 1.5,
                "estimates diverged: {sqnr:?}"
            );
        }
    }
}
