//! L3 coordinator: the simulation-campaign manager.
//!
//! A *campaign* is a grid of [`ExperimentSpec`]s (format x distribution x
//! array depth), each requiring a number of Monte-Carlo samples. The
//! coordinator splits every experiment into engine-sized batch jobs,
//! schedules them over a worker pool (each worker owns its backend — PJRT
//! wrapper types are not `Send`, so engines are built per-thread through
//! [`crate::runtime::build_engine`]), streams per-job aggregates back, and
//! merges them into one [`ColumnAgg`] per experiment.
//!
//! Determinism: job RNG streams are `Pcg64::seeded(job_seed(campaign_seed,
//! spec_index, batch_index))`, so results are independent of worker count
//! and scheduling order (verified in `pool_order_independence`).

pub mod pool;

use crate::distributions::Distribution;
use crate::mac::FormatPair;
use crate::rng::{job_seed, Pcg64};
use crate::runtime::{build_engine, Engine, EngineKind};
use crate::stats::ColumnAgg;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// One grid point of a campaign.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Stable identifier (participates in reports, not in seeding).
    pub id: String,
    pub fmts: FormatPair,
    pub dist_x: Distribution,
    pub dist_w: Distribution,
    pub nr: usize,
    /// Requested Monte-Carlo samples (rounded up to whole engine batches).
    pub samples: usize,
}

/// Campaign-wide settings.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub engine: EngineKind,
    pub artifacts_dir: PathBuf,
    /// Worker threads; 0 = available_parallelism.
    pub workers: usize,
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            engine: EngineKind::Rust,
            artifacts_dir: crate::runtime::ArtifactRegistry::default_dir(),
            workers: 0,
            seed: 0xC1A0_57A7,
        }
    }
}

impl CampaignConfig {
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        }
    }
}

/// Generate one job's inputs and run it on an engine.
pub fn run_job(
    engine: &dyn Engine,
    spec: &ExperimentSpec,
    campaign_seed: u64,
    spec_idx: u64,
    batch_idx: u64,
    batch_samples: usize,
) -> Result<ColumnAgg> {
    let mut rng = Pcg64::seeded(job_seed(campaign_seed, spec_idx, batch_idx));
    let n = batch_samples * spec.nr;
    let mut x = vec![0.0f32; n];
    let mut w = vec![0.0f32; n];
    spec.dist_x.fill_f32(&mut rng, &mut x);
    spec.dist_w.fill_f32(&mut rng, &mut w);
    let batch = engine
        .simulate(&x, &w, spec.nr, spec.fmts)
        .with_context(|| format!("job {}/{batch_idx}", spec.id))?;
    let mut agg = ColumnAgg::new(spec.nr);
    agg.push_batch(&batch);
    Ok(agg)
}

/// Run a whole experiment on one engine (single-threaded convenience used
/// by tests and small figures).
pub fn run_experiment(
    engine: &dyn Engine,
    spec: &ExperimentSpec,
    campaign_seed: u64,
) -> Result<ColumnAgg> {
    let batch = engine.preferred_batch(spec.nr);
    let jobs = spec.samples.div_ceil(batch);
    let mut agg = ColumnAgg::new(spec.nr);
    for j in 0..jobs {
        agg.merge(&run_job(engine, spec, campaign_seed, 0, j as u64, batch)?);
    }
    Ok(agg)
}

/// Run a campaign grid across the worker pool; returns one aggregate per
/// spec, in input order.
pub fn run_campaign(
    specs: &[ExperimentSpec],
    cfg: &CampaignConfig,
) -> Result<Vec<ColumnAgg>> {
    if specs.is_empty() {
        return Ok(Vec::new());
    }
    let specs: Arc<Vec<ExperimentSpec>> = Arc::new(specs.to_vec());

    // plan jobs: (spec_idx, batch_idx, batch_samples)
    // batch sizing must not depend on which engine a worker builds, so we
    // use the canonical artifact batch (2048) — both engines accept it.
    const JOB_BATCH: usize = 2048;
    let mut jobs = Vec::new();
    for (si, spec) in specs.iter().enumerate() {
        let n_jobs = spec.samples.div_ceil(JOB_BATCH);
        for bi in 0..n_jobs {
            jobs.push(pool::Job { spec_idx: si, batch_idx: bi as u64 });
        }
    }

    let seed = cfg.seed;
    let engine_kind = cfg.engine;
    let artifacts = cfg.artifacts_dir.clone();
    let specs_for_worker = Arc::clone(&specs);

    let results = pool::run_jobs(
        jobs,
        cfg.effective_workers(),
        move || {
            let engine = build_engine(engine_kind, &artifacts)?;
            let specs = Arc::clone(&specs_for_worker);
            Ok(move |job: pool::Job| -> Result<(usize, ColumnAgg)> {
                let spec = &specs[job.spec_idx];
                let agg = run_job(
                    engine.as_ref(),
                    spec,
                    seed,
                    job.spec_idx as u64,
                    job.batch_idx,
                    JOB_BATCH,
                )?;
                Ok((job.spec_idx, agg))
            })
        },
    )?;

    // merge per spec
    let mut aggs: Vec<ColumnAgg> =
        specs.iter().map(|s| ColumnAgg::new(s.nr)).collect();
    for (spec_idx, agg) in results {
        aggs[spec_idx].merge(&agg);
    }
    Ok(aggs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FpFormat;
    use crate::runtime::RustEngine;

    fn spec(samples: usize) -> ExperimentSpec {
        ExperimentSpec {
            id: "t".into(),
            fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples,
        }
    }

    #[test]
    fn run_job_deterministic() {
        let e = RustEngine;
        let a = run_job(&e, &spec(64), 7, 0, 0, 64).unwrap();
        let b = run_job(&e, &spec(64), 7, 0, 0, 64).unwrap();
        assert_eq!(a.nf.sum.to_bits(), b.nf.sum.to_bits());
        // different batch index -> different stream
        let c = run_job(&e, &spec(64), 7, 0, 1, 64).unwrap();
        assert_ne!(a.nf.sum.to_bits(), c.nf.sum.to_bits());
    }

    #[test]
    fn run_experiment_rounds_up_to_batches() {
        let e = RustEngine;
        let agg = run_experiment(&e, &spec(3000), 1).unwrap();
        // rounded up to 2 x 2048
        assert_eq!(agg.samples(), 4096);
    }

    #[test]
    fn campaign_matches_single_threaded() {
        let specs = vec![spec(4096), {
            let mut s = spec(2048);
            s.id = "t2".into();
            s.dist_x = Distribution::clipped_gauss4();
            s
        }];
        let cfg = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 4,
            seed: 99,
            ..Default::default()
        };
        let par = run_campaign(&specs, &cfg).unwrap();

        // single-threaded reference with the same seeding scheme
        let e = RustEngine;
        for (si, spec) in specs.iter().enumerate() {
            let jobs = spec.samples.div_ceil(2048);
            let mut agg = ColumnAgg::new(spec.nr);
            for bi in 0..jobs {
                agg.merge(
                    &run_job(&e, spec, 99, si as u64, bi as u64, 2048).unwrap(),
                );
            }
            assert_eq!(par[si].samples(), agg.samples());
            assert_eq!(par[si].nf.sum.to_bits(), agg.nf.sum.to_bits());
            assert_eq!(par[si].sig.sum_sq.to_bits(), agg.sig.sum_sq.to_bits());
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let specs = vec![spec(6144)];
        let mut aggs = Vec::new();
        for workers in [1, 3, 8] {
            let cfg = CampaignConfig {
                engine: EngineKind::Rust,
                workers,
                seed: 5,
                ..Default::default()
            };
            aggs.push(run_campaign(&specs, &cfg).unwrap());
        }
        for pair in aggs.windows(2) {
            assert_eq!(
                pair[0][0].nf.sum.to_bits(),
                pair[1][0].nf.sum.to_bits()
            );
        }
    }

    #[test]
    fn empty_campaign_is_fine() {
        let cfg = CampaignConfig::default();
        assert!(run_campaign(&[], &cfg).unwrap().is_empty());
    }
}
