//! Worker pool built on std threads and channels (no tokio in the vendor
//! set — and the workload is CPU-bound batch compute, not I/O, so a
//! thread-per-worker pool with a shared job queue is the right shape).
//!
//! Each worker constructs its own job-processing closure through a factory
//! (this is where per-thread engines and their reusable
//! `coordinator::JobBuffers` are built — each worker chunks every job it
//! pulls through the same buffers, so the MC hot loop is allocation-free),
//! pulls jobs from the shared queue, and streams results back over a
//! channel. The first error aborts the pool (remaining jobs are drained
//! and dropped).
//!
//! Panic safety: a panicking job closure (or worker factory) is caught
//! with `catch_unwind` and surfaces as a clean `Err` from [`run_jobs`],
//! never as a hang or a cascade. Without the catch, the unwinding worker
//! would poison the shared queue `Mutex`, every other worker's lock
//! would panic in turn, and the caller would see the secondary symptom
//! (`pool lost jobs`, or `expect("pool returned every tile")` in the
//! tile mapper) instead of the root cause. The queue locks additionally
//! recover from poisoning ([`crate::util::sync::lock_recover`] — the
//! queue is a plain iterator, valid after any interrupted `next()`), so
//! even a panic outside the caught region cannot wedge the pool.
//!
//! Every primitive here comes from [`crate::util::sync`], so the whole
//! `run_jobs` protocol — including the result channel — is
//! model-checked by the loom suite (`rust/tests/loom_models.rs`): a
//! panicking job must yield a clean `Err` with no stuck worker in
//! *every* interleaving, not just the ones the unit tests happen to
//! hit.

use crate::util::sync::{channel, lock_recover, panic_msg, spawn_named, Arc, Mutex};
use anyhow::{anyhow, Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// A schedulable unit: one Monte-Carlo batch of one experiment. (The
/// pool itself is generic — the tile mapper schedules plain tile indices
/// through the same [`run_jobs`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Job {
    /// Index into the campaign's spec grid.
    pub spec_idx: usize,
    /// Batch index within that spec (seeds the job's RNG stream).
    pub batch_idx: u64,
}

/// Run `jobs` over `workers` threads.
///
/// `make_worker` is called once per thread and returns the thread's job
/// closure (building any non-`Send` state, e.g. a PJRT engine, inside the
/// thread). Results are returned unordered; scheduling must therefore not
/// affect job semantics (the coordinator seeds jobs by index, not order;
/// the tile mapper re-orders results by tile index before reducing).
///
/// A job closure that panics (rather than returning `Err`) aborts the
/// pool exactly like an error: the panic is caught, remaining jobs are
/// drained, and the caller receives a clean `Err` naming the panic.
pub fn run_jobs<J, T, F, W>(
    jobs: Vec<J>,
    workers: usize,
    make_worker: F,
) -> Result<Vec<T>>
where
    J: Send + 'static,
    T: Send + 'static,
    W: FnMut(J) -> Result<T>,
    F: Fn() -> Result<W> + Send + Sync + 'static,
{
    let total = jobs.len();
    if total == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, total);
    let queue = Arc::new(Mutex::new(jobs.into_iter()));
    let (tx, rx) = channel::<Result<T>>();
    let make_worker = Arc::new(make_worker);

    let mut handles = Vec::with_capacity(workers);
    for wid in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        let make_worker = Arc::clone(&make_worker);
        let handle = spawn_named(format!("grcim-worker-{wid}"), move || {
            let made = catch_unwind(AssertUnwindSafe(&*make_worker)).unwrap_or_else(
                |payload| {
                    Err(anyhow!("worker {wid} init panicked: {}", panic_msg(&*payload)))
                },
            );
            let mut work = match made {
                Ok(w) => w,
                Err(e) => {
                    tx.send(Err(e.context(format!("worker {wid} failed to initialize"))));
                    return;
                }
            };
            loop {
                let job = {
                    let mut q = lock_recover(&queue);
                    q.next()
                };
                let Some(job) = job else { break };
                // a panicking job must not unwind through the pool:
                // it would poison the queue and cascade into every
                // worker — catch it and report a clean error instead
                let res = catch_unwind(AssertUnwindSafe(|| work(job))).unwrap_or_else(
                    |payload| {
                        Err(anyhow!("worker {wid} job panicked: {}", panic_msg(&*payload)))
                    },
                );
                let failed = res.is_err();
                if !tx.send(res) || failed {
                    break; // receiver gone or error sent: stop
                }
            }
        })
        .context("spawning worker")?;
        handles.push(handle);
    }
    drop(tx);

    let mut out = Vec::with_capacity(total);
    let mut first_err: Option<anyhow::Error> = None;
    while let Some(res) = rx.recv() {
        match res {
            Ok(v) => out.push(v),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
                // drain the queue so workers stop picking up new jobs
                let mut q = lock_recover(&queue);
                while q.next().is_some() {}
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    if out.len() != total {
        anyhow::bail!("pool lost jobs: {} of {total} completed", out.len());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn jobs(n: usize) -> Vec<Job> {
        (0..n).map(|i| Job { spec_idx: 0, batch_idx: i as u64 }).collect()
    }

    #[test]
    fn runs_all_jobs() {
        let out = run_jobs(jobs(100), 4, || {
            Ok(|job: Job| Ok(job.batch_idx * 2))
        })
        .unwrap();
        assert_eq!(out.len(), 100);
        let sum: u64 = out.iter().sum();
        assert_eq!(sum, (0..100u64).map(|i| i * 2).sum());
    }

    #[test]
    fn single_worker_and_more_workers_than_jobs() {
        for workers in [1, 64] {
            let out =
                run_jobs(jobs(3), workers, || Ok(|j: Job| Ok(j.batch_idx)))
                    .unwrap();
            assert_eq!(out.len(), 3);
        }
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<u64> =
            run_jobs(vec![], 4, || Ok(|j: Job| Ok(j.batch_idx))).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn propagates_job_error_and_stops() {
        static DONE: AtomicUsize = AtomicUsize::new(0);
        let res: Result<Vec<u64>> = run_jobs(jobs(1000), 4, || {
            Ok(|job: Job| {
                if job.batch_idx == 5 {
                    anyhow::bail!("boom");
                }
                DONE.fetch_add(1, Ordering::Relaxed);
                Ok(job.batch_idx)
            })
        });
        let err = res.unwrap_err().to_string();
        assert!(err.contains("boom"), "{err}");
        // far fewer than 1000 jobs should have completed
        assert!(DONE.load(Ordering::Relaxed) < 500);
    }

    #[test]
    fn propagates_worker_init_error() {
        let res: Result<Vec<u64>> =
            run_jobs(jobs(10), 2, || -> Result<fn(Job) -> Result<u64>> {
                anyhow::bail!("no engine")
            });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("failed to initialize"), "{err}");
    }

    #[test]
    fn panicking_job_is_a_clean_error_not_a_hang() {
        // the regression this pins: a panic inside the job closure used
        // to poison the queue Mutex, cascade panics into every worker,
        // and surface as "pool lost jobs" / the tile mapper's
        // expect("pool returned every tile") instead of the root cause
        static DONE: AtomicUsize = AtomicUsize::new(0);
        let res: Result<Vec<u64>> = run_jobs(jobs(1000), 4, || {
            Ok(|job: Job| {
                if job.batch_idx == 7 {
                    panic!("tile {} exploded", job.batch_idx);
                }
                DONE.fetch_add(1, Ordering::Relaxed);
                Ok(job.batch_idx)
            })
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("tile 7 exploded"), "{err}");
        // the pool aborted early rather than running the full queue
        assert!(DONE.load(Ordering::Relaxed) < 1000);
        // and the pool machinery is still usable afterwards
        let again = run_jobs(jobs(8), 4, || Ok(|j: Job| Ok(j.batch_idx))).unwrap();
        assert_eq!(again.len(), 8);
    }

    #[test]
    fn panicking_worker_init_is_a_clean_error() {
        let res: Result<Vec<u64>> =
            run_jobs(jobs(10), 2, || -> Result<fn(Job) -> Result<u64>> {
                panic!("no backend")
            });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("failed to initialize"), "{err}");
        assert!(err.contains("no backend"), "{err}");
    }

    #[test]
    fn generic_job_types_schedule() {
        // the tile mapper schedules plain indices through the same pool
        let out = run_jobs((0..50usize).collect(), 4, || {
            Ok(|idx: usize| Ok(idx * idx))
        })
        .unwrap();
        let sum: usize = out.iter().sum();
        assert_eq!(sum, (0..50).map(|i| i * i).sum());
    }

    #[test]
    fn per_worker_state_is_isolated() {
        // each worker keeps its own counter; total equals job count
        let out = run_jobs(jobs(64), 4, || {
            let mut local = 0u64;
            Ok(move |_: Job| {
                local += 1;
                Ok(local)
            })
        })
        .unwrap();
        let total: u64 = out.len() as u64;
        assert_eq!(total, 64);
        // max per-worker counter can't exceed total jobs
        assert!(out.iter().all(|&c| c >= 1 && c <= 64));
    }
}
