//! # grcim — Gain-Ranging Compute-in-Memory design-space exploration
//!
//! Reproduction of *"Investigating Energy Bounds of Analog Compute-in-Memory
//! with Local Normalization"* (Rojkov et al., 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the simulation-campaign coordinator, the
//!   multi-backend [`runtime`] (pure-Rust oracle by default; a PJRT engine
//!   executing AOT-lowered HLO artifacts behind the `pjrt` cargo feature),
//!   and every substrate the paper's analysis depends on: FP format
//!   arithmetic, workload distribution generators, a capacitive-network
//!   circuit solver with Pelgrom mismatch Monte Carlo, the paper's
//!   Table II/III energy models, the ADC ENOB requirement solver, and the
//!   figure/table regeneration harness.
//! * **L2 (python/compile/model.py)** — the JAX signal-chain graph, lowered
//!   once to HLO text (`artifacts/*.hlo.txt`).
//! * **L1 (python/compile/kernels/grmac.py)** — the fused Pallas Monte-Carlo
//!   kernel inside that graph.
//!
//! The **default build is self-contained**: no artifacts, no Python, no
//! native XLA toolchain — every campaign, figure, test, and bench runs on
//! the deterministic [`mac::simulate_column`] oracle. Builds with
//! `--features pjrt` additionally compile the PJRT path, which executes
//! `artifacts/*.hlo.txt` when present (lowered once by
//! `python/compile/aot.py`) and falls back to the oracle otherwise.
//!
//! Entry points: the [`coordinator`] runs sweep campaigns over the
//! [`runtime`] engines; [`figures`] regenerates every table and figure of
//! the paper's evaluation; [`tile`] maps layer-scale GEMMs onto GR-MAC
//! arrays and [`model`] chains them into full-network energy reports;
//! the [`server`] keeps the process resident and
//! answers spec-point queries over TCP from a spec-keyed result cache;
//! `examples/` shows the public API; the golden regression suite
//! (`rust/tests/golden.rs`) pins exact campaign numbers.
//!
//! # Quickstart
//!
//! One Monte-Carlo experiment end-to-end — simulate a column MAC
//! campaign on the pure-Rust oracle, then solve the paper's ADC
//! requirement from the aggregate:
//!
//! ```
//! use grcim::coordinator::{run_experiment, ExperimentSpec};
//! use grcim::distributions::Distribution;
//! use grcim::formats::FpFormat;
//! use grcim::mac::FormatPair;
//! use grcim::runtime::RustEngine;
//! use grcim::spec::{delta_enob, SpecConfig};
//!
//! let spec = ExperimentSpec {
//!     id: "quickstart".into(),
//!     fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
//!     dist_x: Distribution::Uniform,
//!     dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
//!     nr: 32,
//!     samples: 2048,
//!     sampler: Default::default(),
//! };
//! let agg = run_experiment(&RustEngine, &spec, 7)?;
//! assert_eq!(agg.samples(), 2048);
//! // the paper's headline: gain ranging relaxes the ADC requirement
//! assert!(delta_enob(&agg, SpecConfig::default()) > 1.0);
//! # Ok::<(), anyhow::Error>(())
//! ```

#![warn(missing_docs)]
// the library proper is entirely safe code; the only `unsafe` in the
// workspace is the counting GlobalAlloc in benches/hotpath.rs, a
// separate crate target this lint does not reach
#![deny(unsafe_code)]

pub mod analog;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod distributions;
pub mod energy;
pub mod explore;
pub mod figures;
pub mod formats;
pub mod mac;
pub mod model;
pub mod nn;
pub mod propcheck;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod stats;
pub mod tile;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
