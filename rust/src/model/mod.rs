//! Model-scale energy pipeline — chain GR-MAC tile layers into
//! full-network reports (the end-to-end accounting IMAGINE and the
//! KU Leuven analog-vs-digital benchmarking model argue is what makes
//! CIM energy claims comparable; paper Sec. V outlook).
//!
//! The tile mapper ([`crate::tile`]) prices one GEMM layer. Real
//! workloads — the paper's LLM/edge motivation — run *networks* of
//! layers, and what happens **between** the layers decides whether the
//! GR-MAC's ADC invariance survives composition: every layer's digital
//! output must be requantized to the array's input format before it can
//! drive the next layer's DACs, and every layer sees activation
//! statistics shaped by the layers before it, so its spec-solved ADC is
//! data-dependent in a way no single-layer evaluation captures.
//!
//! This module closes that gap:
//!
//! * [`ModelSpec`] / [`parse_model`] — a named sequence of GEMM layers:
//!   `mlp:<d0>x<d1>x...` MLP presets, the `block:<d_model>` transformer
//!   block (expanding to the [`crate::tile::parse_shape`] names
//!   `qkv`/`attn-out`/`mlp-up`/`mlp-down`), or an explicit comma list of
//!   shape strings;
//! * [`exec`] — the layer-by-layer executor: per-layer static
//!   calibration (max-|x| scale), inter-layer requantization to the
//!   input format, optional per-layer [`crate::workload::EmpiricalDist`]
//!   fitting of the activations feeding each layer, every GEMM routed
//!   through [`crate::tile::mapper::gemm_with_engine`] (or the pooled
//!   [`crate::tile::run_layer_with_data`], bit-identical at any worker
//!   count), and the float reference chain for end-to-end SQNR;
//! * [`ModelReport`] — per-layer [`crate::tile::LayerReport`]s plus
//!   requantization SQNRs and activation statistics, aggregated into
//!   network totals: energy, fJ/MAC, the ADC-resolution histogram across
//!   every tile of every layer, end-to-end SQNR vs. the float chain, and
//!   (for the trained-MLP path, [`crate::nn::cim_model_report`]) the
//!   classification-accuracy delta vs. float inference.
//!
//! Consumers: [`crate::nn::cim_forward_batch`] is a thin wrapper over
//! [`exec::forward_stages`]; `grcim model` and the serve layer's `model`
//! request evaluate model strings via [`exec::run_model`].
//!
//! # Example
//!
//! ```
//! use grcim::coordinator::CampaignConfig;
//! use grcim::model::{parse_model, ModelSpec};
//! use grcim::runtime::EngineKind;
//!
//! let spec = ModelSpec::preset("mlp:16x12x8", 2)?;
//! assert_eq!(spec.layers.len(), 2);
//! let campaign = CampaignConfig {
//!     engine: EngineKind::Rust,
//!     workers: 2,
//!     seed: 7,
//!     ..Default::default()
//! };
//! let res = grcim::model::run_model(&spec, &campaign)?;
//! assert_eq!(res.report.layers.len(), 2);
//! assert!(res.report.total_fj() > 0.0);
//! assert!(res.report.to_figure_result().all_hold());
//! // explicit layer lists parse too
//! assert_eq!(parse_model("qkv:8,attn-out:8", 2)?.len(), 2);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod exec;

pub use exec::{forward_stages, run_model, ForwardOpts, Runner, Stage, MODEL_STREAM};

use crate::distributions::Distribution;
use crate::energy::{energy_per_op, CimArch, TechParams};
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};
use crate::tile::{parse_shape, AdcPolicy, GemmShape, LayerReport, TileConfig, MAX_TILE_ENOB};
use anyhow::{bail, Context, Result};

/// Largest number of layers one model may chain — bounds serve-side work
/// and keeps the MAC sum far from `u64` overflow (64 layers x 2^60 max
/// MACs each still fits u64 via saturating arithmetic; requests are
/// rejected long before that by the serve MAC cap).
pub const MAX_MODEL_LAYERS: usize = 64;

/// One GEMM layer of a model: a label, its dimensions, and an optional
/// per-layer format override (layers without one use the model's base
/// [`TileConfig`] formats).
#[derive(Debug, Clone)]
pub struct ModelLayer {
    /// Layer label (reports only; not part of seeding or cache identity).
    pub name: String,
    /// GEMM dimensions (`m` is the shared token/batch dimension).
    pub shape: GemmShape,
    /// Per-layer input/weight format override.
    pub fmts: Option<FormatPair>,
}

/// A full model evaluation request: the layer chain, the array
/// configuration every layer maps onto, and the workload distributions
/// generating the model input and the per-layer weights. Consumed by
/// [`exec::run_model`].
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Model label (reports only).
    pub name: String,
    /// The layer chain, input to output (see [`parse_model`]).
    pub layers: Vec<ModelLayer>,
    /// Base array configuration (formats, geometry, architecture, ADC
    /// policy, technology parameters) for layers without an override.
    pub cfg: TileConfig,
    /// Model-input activation distribution.
    pub dist_x: Distribution,
    /// Weight distribution (every layer draws its own stream from it).
    pub dist_w: Distribution,
    /// Apply ReLU between layers (the MLP convention; `mlp:` presets set
    /// this, shape-list models leave it off).
    pub relu: bool,
    /// Fit an [`crate::workload::EmpiricalDist`] to the (scaled)
    /// activations feeding each layer and report its statistics.
    pub fit_activations: bool,
}

impl ModelSpec {
    /// Resolve a model string with the paper's default array: FP(4,2)
    /// inputs vs max-entropy FP4 weights on 32x32 gr-unit tiles with
    /// per-tile spec-solved ADCs. `mlp:` presets enable ReLU.
    pub fn preset(model: &str, tokens: usize) -> Result<ModelSpec> {
        let layers = parse_model(model, tokens)?;
        let fmt = FpFormat::fp(4, 2);
        let w_fmt = FpFormat::fp4_e2m1();
        Ok(ModelSpec {
            name: model.to_string(),
            layers,
            cfg: TileConfig {
                nr: 32,
                nc: 32,
                fmts: FormatPair::new(fmt, w_fmt),
                arch: CimArch::GrUnit,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(w_fmt),
            relu: model.starts_with("mlp:"),
            fit_activations: false,
        })
    }

    /// Total useful MACs over the chain (saturating; bounded by
    /// [`MAX_MODEL_LAYERS`] x the per-shape bound).
    pub fn macs(&self) -> u64 {
        self.layers.iter().fold(0u64, |acc, l| acc.saturating_add(l.shape.macs()))
    }

    /// The effective [`TileConfig`] of one layer (base config with the
    /// layer's format override applied).
    pub fn layer_cfg(&self, li: usize) -> TileConfig {
        let mut cfg = self.cfg;
        if let Some(fmts) = self.layers[li].fmts {
            cfg.fmts = fmts;
        }
        cfg
    }
}

/// Parse a model string into its layer chain:
///
/// | value | layers |
/// |---|---|
/// | `mlp:<d0>x<d1>x...x<dk>` | `fc<i>: [tokens x d_{i-1}] . [d_{i-1} x d_i]` (k >= 2 dims) |
/// | `block:<d>` | `qkv:<d>, attn-out:<d>, mlp-up:<d>, mlp-down:<d>` |
/// | `<shape>,<shape>,...` | explicit [`parse_shape`] entries |
///
/// Chaining rule: every layer's reduction width `K` must not exceed the
/// previous layer's output width `N` (`K < N` feeds the leading `K`
/// features — the documented truncation that stands in for attention
/// between `qkv` and `attn-out`; see `docs/THEORY.md`), and every layer
/// shares the token dimension `M`.
pub fn parse_model(s: &str, tokens: usize) -> Result<Vec<ModelLayer>> {
    if tokens == 0 {
        bail!("tokens must be positive");
    }
    let layers: Vec<ModelLayer> = if let Some(arg) = s.strip_prefix("mlp:") {
        let dims: Vec<usize> = arg
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .with_context(|| format!("model '{s}': '{d}' is not a dimension"))
            })
            .collect::<Result<_>>()?;
        if dims.len() < 2 {
            bail!("model '{s}': mlp needs at least two dims, 'mlp:<d0>x<d1>[x...]'");
        }
        dims.windows(2)
            .enumerate()
            .map(|(i, d)| {
                // parse_shape re-validates positivity and the 2^20 bound
                let shape = parse_shape(&format!("gemm:{tokens}x{}x{}", d[0], d[1]), 1)?;
                Ok(ModelLayer { name: format!("fc{i}"), shape, fmts: None })
            })
            .collect::<Result<_>>()?
    } else if let Some(arg) = s.strip_prefix("block:") {
        ["qkv", "attn-out", "mlp-up", "mlp-down"]
            .iter()
            .map(|kind| {
                let name = format!("{kind}:{arg}");
                let shape = parse_shape(&name, tokens)?;
                Ok(ModelLayer { name, shape, fmts: None })
            })
            .collect::<Result<_>>()?
    } else {
        s.split(',')
            .map(str::trim)
            .filter(|e| !e.is_empty())
            .map(|e| {
                let shape = parse_shape(e, tokens)?;
                Ok(ModelLayer { name: e.to_string(), shape, fmts: None })
            })
            .collect::<Result<_>>()?
    };
    if layers.is_empty() {
        bail!("model '{s}' has no layers");
    }
    if layers.len() > MAX_MODEL_LAYERS {
        bail!("model '{s}' has {} layers (max {MAX_MODEL_LAYERS})", layers.len());
    }
    check_chain(s, &layers)?;
    Ok(layers)
}

/// Validate the chaining rule (shared by [`parse_model`] and the
/// executor, which also accepts hand-built layer lists).
pub fn check_chain(what: &str, layers: &[ModelLayer]) -> Result<()> {
    if layers.is_empty() {
        bail!("model '{what}' has no layers");
    }
    let m = layers[0].shape.m;
    for (i, l) in layers.iter().enumerate() {
        if l.shape.m != m {
            bail!(
                "model '{what}': layer {i} ('{}') has M={} but the chain runs at M={m}",
                l.name,
                l.shape.m
            );
        }
        if i > 0 {
            let prev = layers[i - 1].shape.n;
            if l.shape.k > prev {
                bail!(
                    "model '{what}': layer {i} ('{}') needs K={} inputs but layer {} \
                     only produces N={prev}",
                    l.name,
                    l.shape.k,
                    i - 1
                );
            }
        }
    }
    Ok(())
}

/// Statistics of the (scaled) activation tensor feeding one layer — the
/// [`crate::workload::EmpiricalDist`] fit summary of the inter-layer
/// traffic (requested via [`ModelSpec::fit_activations`]).
#[derive(Debug, Clone, Copy)]
pub struct ActStats {
    /// Dynamic range of the nonzero activations, bits.
    pub dr_bits: f64,
    /// Robust core spread ((Q(.84) - Q(.16)) / 2 on the normalized scale).
    pub sigma_core: f64,
    /// Mass beyond the fit's outlier threshold.
    pub outlier_mass: f64,
    /// Mean of the normalized activations.
    pub mean: f64,
    /// Standard deviation of the normalized activations.
    pub std: f64,
}

/// One executed layer of a model: the tile-level report plus the
/// inter-layer bookkeeping that only exists at model scale.
#[derive(Debug, Clone)]
pub struct LayerOutcome {
    /// The tile mapper's per-layer evaluation.
    pub report: LayerReport,
    /// Static per-tensor calibration scale (max |activation|) applied
    /// before requantization.
    pub a_scale: f64,
    /// SQNR of the inter-layer requantization to the input format, dB
    /// (scaled activations vs their format-quantized f32 encoding).
    pub requant_sqnr_db: f64,
    /// Fit summary of the activations feeding this layer (when
    /// [`ModelSpec::fit_activations`] is set and the fit succeeds).
    pub act_stats: Option<ActStats>,
}

/// The network-level evaluation: per-layer outcomes plus model totals.
/// Produced by [`exec::forward_stages`] / [`exec::run_model`]; rendered
/// by [`ModelReport::to_figure_result`].
#[derive(Debug, Clone)]
pub struct ModelReport {
    /// Model label.
    pub name: String,
    /// Token/batch dimension shared by every layer.
    pub tokens: usize,
    /// Per-layer outcomes, input to output.
    pub layers: Vec<LayerOutcome>,
    /// End-to-end output SQNR vs the exact float chain, dB (NaN on the
    /// no-reference fast path).
    pub sqnr_db: f64,
    /// Float-inference classification accuracy (trained-MLP path only).
    pub accuracy_float: Option<f64>,
    /// CIM-inference classification accuracy (trained-MLP path only).
    pub accuracy_cim: Option<f64>,
}

impl ModelReport {
    /// Total model energy: sum of the per-layer totals, fJ.
    pub fn total_fj(&self) -> f64 {
        self.layers.iter().map(|l| l.report.total_fj()).sum()
    }

    /// Total useful MACs over the chain.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.report.shape.macs()).sum()
    }

    /// Energy per useful MAC, fJ.
    pub fn fj_per_mac(&self) -> f64 {
        self.total_fj() / self.macs() as f64
    }

    /// Energy per operation (one MAC = two ops, the paper's convention).
    pub fn fj_per_op(&self) -> f64 {
        self.fj_per_mac() / 2.0
    }

    /// CIM-minus-float classification-accuracy delta (trained-MLP path).
    pub fn accuracy_delta(&self) -> Option<f64> {
        match (self.accuracy_cim, self.accuracy_float) {
            (Some(c), Some(f)) => Some(c - f),
            _ => None,
        }
    }

    /// ADC-resolution histogram across every tile of every layer:
    /// (floor(ENOB), tile count), ascending.
    pub fn enob_histogram(&self) -> Vec<(i64, usize)> {
        let mut bins = std::collections::BTreeMap::new();
        for l in &self.layers {
            for t in &l.report.tiles {
                *bins.entry(t.enob.floor() as i64).or_insert(0usize) += 1;
            }
        }
        bins.into_iter().collect()
    }

    /// Number of tiles across every layer.
    pub fn tile_count(&self) -> usize {
        self.layers.iter().map(|l| l.report.tiles.len()).sum()
    }

    /// Mean per-tile ADC resolution across the whole model, bits.
    pub fn enob_mean(&self) -> f64 {
        let n = self.tile_count();
        let sum: f64 = self
            .layers
            .iter()
            .flat_map(|l| l.report.tiles.iter().map(|t| t.enob))
            .sum();
        sum / n as f64
    }

    /// Render the report as tables + invariant checks (the `grcim model`
    /// output and the serve layer's `model` response).
    pub fn to_figure_result(&self) -> FigureResult {
        let mut fr = FigureResult::new("model");

        let mut summary = Table::new("model summary", &["metric", "value"]);
        let mut kv = |k: &str, v: String| summary.row(vec![k.into(), v]);
        kv("model", self.name.clone());
        kv("tokens", self.tokens.to_string());
        kv("layers", self.layers.len().to_string());
        kv("tiles", self.tile_count().to_string());
        kv("macs", self.macs().to_string());
        kv("enob_mean", Table::f(self.enob_mean()));
        kv("end_to_end_sqnr_db", Table::f(self.sqnr_db));
        kv("total_fj", Table::f(self.total_fj()));
        kv("fj_per_mac", Table::f(self.fj_per_mac()));
        kv("fj_per_op", Table::f(self.fj_per_op()));
        if let (Some(f), Some(c)) = (self.accuracy_float, self.accuracy_cim) {
            kv("accuracy_float", Table::f(f));
            kv("accuracy_cim", Table::f(c));
            kv("accuracy_delta", Table::f(c - f));
        }
        fr.tables.push(summary);

        let mut layers = Table::new(
            "layers",
            &[
                "layer", "shape", "tiles", "enob_mean", "sqnr_db", "requant_db", "act_dr_bits",
                "act_outliers", "total_fj", "fj_per_mac",
            ],
        );
        for l in &self.layers {
            let r = &l.report;
            let (dr, mass) = match &l.act_stats {
                Some(s) => (Table::f(s.dr_bits), Table::f(s.outlier_mass)),
                None => ("-".into(), "-".into()),
            };
            layers.row(vec![
                r.name.clone(),
                r.shape.to_string(),
                r.tiles.len().to_string(),
                Table::f(r.enob_mean()),
                Table::f(r.sqnr_db),
                Table::f(l.requant_sqnr_db),
                dr,
                mass,
                Table::f(r.total_fj()),
                Table::f(r.fj_per_mac()),
            ]);
        }
        fr.tables.push(layers);

        let mut hist = Table::new("adc histogram (all layers)", &["enob_bin", "tiles", "pct"]);
        let tiles = self.tile_count();
        for (bin, count) in self.enob_histogram() {
            hist.row(vec![
                format!("[{bin},{})", bin + 1),
                count.to_string(),
                Table::f(100.0 * count as f64 / tiles as f64),
            ]);
        }
        fr.tables.push(hist);

        // ---- invariant checks (distribution-independent) ----
        // model totals must reconcile with independent energy::arch
        // evaluations at the reported per-tile resolutions, layer by layer
        let mut independent = 0.0;
        for l in &self.layers {
            let r = &l.report;
            let mvm_ops = (2 * r.cfg.nr * r.cfg.nc * r.shape.m) as f64;
            let tiles_fj: f64 = r
                .tiles
                .iter()
                .map(|t| {
                    energy_per_op(r.cfg.arch, r.cfg.fmts, r.cfg.nr, r.cfg.nc, t.enob, &r.cfg.tech)
                        .total()
                        * mvm_ops
                })
                .sum();
            independent += tiles_fj + r.reduction_fj + r.global_norm_fj;
        }
        let total = self.total_fj();
        let rel = (independent - total).abs() / total.max(1e-300);
        fr.check(
            "layer energy totals reconcile with energy::arch",
            "sum of independent per-tile evaluations",
            format!("rel diff {rel:.3e}"),
            rel < 1e-9,
        );
        let covered: u64 =
            self.layers.iter().flat_map(|l| l.report.tiles.iter().map(|t| t.macs)).sum();
        fr.check(
            "tile grids cover every layer GEMM exactly once",
            format!("{} macs", self.macs()),
            format!("{covered} macs"),
            covered == self.macs(),
        );
        let enob_ok = self
            .layers
            .iter()
            .flat_map(|l| l.report.tiles.iter())
            .all(|t| t.enob.is_finite() && (0.0..=MAX_TILE_ENOB).contains(&t.enob));
        fr.check(
            "per-tile ADC resolutions are finite and physical",
            format!("0 <= enob <= {MAX_TILE_ENOB}"),
            format!("mean {}", Table::f(self.enob_mean())),
            enob_ok,
        );
        let requant_ok = self.layers.iter().all(|l| l.requant_sqnr_db.is_finite());
        fr.check(
            "model SQNR, requantization SQNRs, and energy totals are finite",
            "finite",
            format!("e2e {} dB, total {} fJ", Table::f(self.sqnr_db), Table::f(total)),
            self.sqnr_db.is_finite() && total.is_finite() && requant_ok,
        );
        fr
    }
}

/// A completed model evaluation: the report plus the network's final
/// activations (row-major `[M][N_last]`, float domain).
#[derive(Debug, Clone)]
pub struct ModelResult {
    /// Per-layer and network-level evaluation.
    pub report: ModelReport,
    /// Final-layer activations after the epilogue, row-major.
    pub y: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_preset_expands_to_a_chain() {
        let layers = parse_model("mlp:24x16x12x8", 4).unwrap();
        assert_eq!(layers.len(), 3);
        assert_eq!(layers[0].shape, GemmShape { m: 4, k: 24, n: 16 });
        assert_eq!(layers[1].shape, GemmShape { m: 4, k: 16, n: 12 });
        assert_eq!(layers[2].shape, GemmShape { m: 4, k: 12, n: 8 });
        assert_eq!(layers[0].name, "fc0");
        assert!(ModelSpec::preset("mlp:24x16x8", 4).unwrap().relu);
    }

    #[test]
    fn block_preset_reuses_named_shapes() {
        let layers = parse_model("block:16", 2).unwrap();
        assert_eq!(layers.len(), 4);
        assert_eq!(layers[0].shape, GemmShape { m: 2, k: 16, n: 48 });
        assert_eq!(layers[1].shape, GemmShape { m: 2, k: 16, n: 16 });
        assert_eq!(layers[2].shape, GemmShape { m: 2, k: 16, n: 64 });
        assert_eq!(layers[3].shape, GemmShape { m: 2, k: 64, n: 16 });
        assert!(!ModelSpec::preset("block:16", 2).unwrap().relu);
    }

    #[test]
    fn explicit_lists_chain_and_mischains_are_errors() {
        let layers = parse_model("gemm:2x8x6, gemm:2x6x4", 9).unwrap();
        assert_eq!(layers.len(), 2);
        // K < previous N is the documented truncation, K > N is an error
        assert!(parse_model("gemm:2x8x6,gemm:2x4x4", 9).is_ok());
        let err = parse_model("gemm:2x8x6,gemm:2x7x4", 9).unwrap_err().to_string();
        assert!(err.contains("only produces"), "{err}");
        // mismatched token dimension
        assert!(parse_model("gemm:2x8x6,gemm:3x6x4", 9).is_err());
    }

    #[test]
    fn malformed_models_are_clean_errors() {
        for bad in [
            "mlp:",         // no dims
            "mlp:16",       // one dim
            "mlp:16xabc",   // non-numeric
            "mlp:16x0",     // zero dim
            "block:",       // empty d
            "block:0",      // zero d
            "warp:64",      // unknown shape kind
            "",             // empty list
            ",,",           // empty entries only
            "gemm:2x8",     // bad shape in list
        ] {
            assert!(parse_model(bad, 4).is_err(), "{bad}");
        }
        assert!(parse_model("mlp:16x8", 0).is_err());
        // the layer-count bound holds
        let many = vec!["16"; MAX_MODEL_LAYERS + 2].join("x");
        assert!(parse_model(&format!("mlp:{many}"), 2).is_err());
    }

    #[test]
    fn empty_hand_built_chains_are_errors_not_panics() {
        // ModelSpec fields are public; an empty hand-built layer list
        // must fail cleanly through every entry point
        assert!(check_chain("empty", &[]).is_err());
        let mut spec = ModelSpec::preset("mlp:8x8", 2).unwrap();
        spec.layers.clear();
        let campaign = crate::coordinator::CampaignConfig::default();
        assert!(super::run_model(&spec, &campaign).is_err());
    }

    #[test]
    fn spec_macs_and_layer_cfg_overrides() {
        let mut spec = ModelSpec::preset("mlp:8x8x8", 2).unwrap();
        assert_eq!(spec.macs(), 2 * (2 * 8 * 8) as u64);
        let wide = FormatPair::new(FpFormat::fp(5, 2), FpFormat::fp4_e2m1());
        spec.layers[1].fmts = Some(wide);
        assert_eq!(spec.layer_cfg(0).fmts.x, FpFormat::fp(4, 2));
        assert_eq!(spec.layer_cfg(1).fmts.x, FpFormat::fp(5, 2));
    }
}
