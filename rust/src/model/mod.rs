//! Model-scale energy pipeline — chain GR-MAC tile layers into
//! full-network reports (the end-to-end accounting IMAGINE and the
//! KU Leuven analog-vs-digital benchmarking model argue is what makes
//! CIM energy claims comparable; paper Sec. V outlook).
//!
//! The tile mapper ([`crate::tile`]) prices one GEMM layer. Real
//! workloads — the paper's LLM/edge motivation — run *networks* of
//! layers, and what happens **between** the layers decides whether the
//! GR-MAC's ADC invariance survives composition: every layer's digital
//! output must be requantized to the array's input format before it can
//! drive the next layer's DACs, and every layer sees activation
//! statistics shaped by the layers before it, so its spec-solved ADC is
//! data-dependent in a way no single-layer evaluation captures.
//!
//! This module closes that gap:
//!
//! * [`ModelSpec`] / [`parse_model`] — a named sequence of layers:
//!   `mlp:<d0>x<d1>x...` MLP presets, the `block:<d_model>` transformer
//!   block (expanding to the [`crate::tile::parse_shape`] names
//!   `qkv`/`attn-out`/`mlp-up`/`mlp-down`), multi-head
//!   `transformer:<d_model>x<heads>x<layers>` blocks with *real*
//!   attention stages ([`attn`]: QK^T and A·V as tile GEMMs around an
//!   exact digital f32 softmax), the decode-phase
//!   `decode:<d_model>x<heads>x<ctx>` KV-cache GEMV scenario, or an
//!   explicit comma list of shape strings (`conv:` entries run through
//!   the [`crate::tile::im2col`] flattener);
//! * [`exec`] — the layer-by-layer executor: per-layer static
//!   calibration (max-|x| scale), inter-layer requantization to the
//!   input format, optional per-layer [`crate::workload::EmpiricalDist`]
//!   fitting of the activations feeding each layer, every GEMM routed
//!   through [`crate::tile::mapper::gemm_with_engine`] (or the pooled
//!   [`crate::tile::run_layer_with_data`], bit-identical at any worker
//!   count), and the float reference chain for end-to-end SQNR;
//! * [`ModelReport`] — per-layer [`crate::tile::LayerReport`]s plus
//!   requantization SQNRs and activation statistics, aggregated into
//!   network totals: energy, fJ/MAC, the ADC-resolution histogram across
//!   every tile of every layer, end-to-end SQNR vs. the float chain, and
//!   (for the trained-MLP path, [`crate::nn::cim_model_report`]) the
//!   classification-accuracy delta vs. float inference.
//!
//! Consumers: [`crate::nn::cim_forward_batch`] is a thin wrapper over
//! [`exec::forward_stages`]; `grcim model` and the serve layer's `model`
//! request evaluate model strings via [`exec::run_model`].
//!
//! # Example
//!
//! ```
//! use grcim::coordinator::CampaignConfig;
//! use grcim::model::{parse_model, ModelSpec};
//! use grcim::runtime::EngineKind;
//!
//! let spec = ModelSpec::preset("mlp:16x12x8", 2)?;
//! assert_eq!(spec.layers.len(), 2);
//! let campaign = CampaignConfig {
//!     engine: EngineKind::Rust,
//!     workers: 2,
//!     seed: 7,
//!     ..Default::default()
//! };
//! let res = grcim::model::run_model(&spec, &campaign)?;
//! assert_eq!(res.report.layers.len(), 2);
//! assert!(res.report.total_fj() > 0.0);
//! assert!(res.report.to_figure_result().all_hold());
//! // explicit layer lists parse too
//! assert_eq!(parse_model("qkv:8,attn-out:8", 2)?.len(), 2);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod attn;
pub mod exec;

pub use attn::{run_attention, softmax_rows_f32, AttnKvCache, AttnOutcome, AttnSpec};
pub use exec::{forward_stages, run_model, ForwardOpts, Runner, Stage, MODEL_STREAM};

use crate::distributions::Distribution;
use crate::energy::{energy_per_op, CimArch, TechParams};
use crate::formats::FpFormat;
use crate::mac::FormatPair;
use crate::report::{FigureResult, Table};
use crate::tile::shapes::MAX_DIM;
use crate::tile::{
    parse_shape, AdcPolicy, ConvShape, GemmShape, LayerReport, TileConfig, MAX_TILE_ENOB,
};
use anyhow::{bail, Context, Result};

/// Largest number of layers one model may chain — bounds serve-side work
/// and keeps the MAC sum far from `u64` overflow (64 layers x 2^60 max
/// MACs each still fits u64 via saturating arithmetic; requests are
/// rejected long before that by the serve MAC cap).
pub const MAX_MODEL_LAYERS: usize = 64;

/// What a model layer computes — a plain GEMM, an im2col-flattened
/// convolution, or a real attention stage (QK^T / softmax / A·V, see
/// [`attn`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// A plain GEMM (the original model-layer kind).
    Gemm,
    /// An im2col-flattened convolution; `shape` is its
    /// [`ConvShape::gemm_shape`]. Only valid as the first layer (the
    /// model input is the image).
    Conv(ConvShape),
    /// A multi-head attention stage. `ctx: None` = prefill
    /// self-attention over the fused QKV input (`K = 3·d_model`,
    /// score width `S = M`); `ctx: Some(c)` = decode over a frozen KV
    /// cache of `c` entries (`K = d_model`, the Q slice).
    Attention {
        /// Attention heads (`d_model % heads == 0`).
        heads: usize,
        /// Decode-phase KV-cache depth; `None` = prefill.
        ctx: Option<usize>,
    },
}

/// One layer of a model: a label, its dimensions, its kind, and an
/// optional per-layer format override (layers without one use the
/// model's base [`TileConfig`] formats).
#[derive(Debug, Clone)]
pub struct ModelLayer {
    /// Layer label (reports only; not part of seeding or cache identity).
    pub name: String,
    /// GEMM dimensions (`m` is the shared token/batch dimension). For
    /// attention this is the *chain* shape (`K` consumed features, `N`
    /// produced features); the arithmetic is [`ModelLayer::macs`].
    pub shape: GemmShape,
    /// What the layer computes.
    pub kind: LayerKind,
    /// Per-layer input/weight format override.
    pub fmts: Option<FormatPair>,
}

impl ModelLayer {
    /// True multiply-accumulates of this layer (saturating). GEMM/conv:
    /// the flattened GEMM's MACs. Attention: `2·M·S·d_model` (QK^T plus
    /// A·V over score width `S` = ctx for decode, `M` for prefill) —
    /// matching the virtual `M×(2S)×d_model` shape its combined
    /// [`LayerReport`] carries.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Attention { ctx, .. } => {
                let s = ctx.unwrap_or(self.shape.m) as u64;
                2u64.saturating_mul(self.shape.m as u64)
                    .saturating_mul(s)
                    .saturating_mul(self.shape.n as u64)
            }
            _ => self.shape.macs(),
        }
    }

    /// Peak operand-slab elements the executor materializes for this
    /// layer (saturating) — what the serve layer's slab cap audits.
    /// Attention grows with `S` twice over: the KV cache (decode) and
    /// the per-head probability matrices (`heads·M·S`, held twice: raw
    /// and requantized) — the O(ctx²) blow-up the caps must see.
    pub fn slab_elems(&self) -> u64 {
        let (m, k, n) = (self.shape.m as u64, self.shape.k as u64, self.shape.n as u64);
        let sum = |vals: &[u64]| vals.iter().fold(0u64, |a, &v| a.saturating_add(v));
        match self.kind {
            LayerKind::Gemm => sum(&[m.saturating_mul(k), n.saturating_mul(k), m.saturating_mul(n)]),
            LayerKind::Conv(cs) => sum(&[
                cs.img_elems() as u64,
                m.saturating_mul(k),
                n.saturating_mul(k),
                m.saturating_mul(n),
            ]),
            LayerKind::Attention { heads, ctx } => {
                let s = ctx.map_or(m, |c| c as u64);
                let kv = if ctx.is_some() { 2u64.saturating_mul(s).saturating_mul(n) } else { 0 };
                let probs =
                    2u64.saturating_mul(heads as u64).saturating_mul(m).saturating_mul(s);
                sum(&[m.saturating_mul(k), m.saturating_mul(n), kv, probs])
            }
        }
    }
}

/// A full model evaluation request: the layer chain, the array
/// configuration every layer maps onto, and the workload distributions
/// generating the model input and the per-layer weights. Consumed by
/// [`exec::run_model`].
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Model label (reports only).
    pub name: String,
    /// The layer chain, input to output (see [`parse_model`]).
    pub layers: Vec<ModelLayer>,
    /// Base array configuration (formats, geometry, architecture, ADC
    /// policy, technology parameters) for layers without an override.
    pub cfg: TileConfig,
    /// Model-input activation distribution.
    pub dist_x: Distribution,
    /// Weight distribution (every layer draws its own stream from it).
    pub dist_w: Distribution,
    /// Apply ReLU between layers (the MLP convention; `mlp:` presets set
    /// this, shape-list models leave it off).
    pub relu: bool,
    /// Fit an [`crate::workload::EmpiricalDist`] to the (scaled)
    /// activations feeding each layer and report its statistics.
    pub fit_activations: bool,
}

impl ModelSpec {
    /// Resolve a model string with the paper's default array: FP(4,2)
    /// inputs vs max-entropy FP4 weights on 32x32 gr-unit tiles with
    /// per-tile spec-solved ADCs. `mlp:` presets enable ReLU.
    pub fn preset(model: &str, tokens: usize) -> Result<ModelSpec> {
        let layers = parse_model(model, tokens)?;
        let fmt = FpFormat::fp(4, 2);
        let w_fmt = FpFormat::fp4_e2m1();
        Ok(ModelSpec {
            name: model.to_string(),
            layers,
            cfg: TileConfig {
                nr: 32,
                nc: 32,
                fmts: FormatPair::new(fmt, w_fmt),
                arch: CimArch::GrUnit,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(w_fmt),
            relu: model.starts_with("mlp:"),
            fit_activations: false,
        })
    }

    /// Total useful MACs over the chain (saturating; bounded by
    /// [`MAX_MODEL_LAYERS`] x the per-shape bound), per-kind via
    /// [`ModelLayer::macs`] — attention counts `2·M·S·d_model`.
    pub fn macs(&self) -> u64 {
        self.layers.iter().fold(0u64, |acc, l| acc.saturating_add(l.macs()))
    }

    /// The effective [`TileConfig`] of one layer (base config with the
    /// layer's format override applied).
    pub fn layer_cfg(&self, li: usize) -> TileConfig {
        let mut cfg = self.cfg;
        if let Some(fmts) = self.layers[li].fmts {
            cfg.fmts = fmts;
        }
        cfg
    }
}

/// Parse an `<a>x<b>x<c>` triple (the `transformer:` / `decode:`
/// preset arguments).
fn parse_triple(s: &str, arg: &str, what: &str) -> Result<(usize, usize, usize)> {
    let dims: Vec<usize> = arg
        .split('x')
        .map(|d| {
            d.parse::<usize>().with_context(|| format!("model '{s}': '{d}' is not a dimension"))
        })
        .collect::<Result<_>>()?;
    let &[a, b, c] = dims.as_slice() else {
        bail!("model '{s}' needs exactly three dims, '{what}'");
    };
    Ok((a, b, c))
}

/// Validate a `(d_model, heads)` pair shared by the attention presets.
fn check_heads(s: &str, d: usize, heads: usize) -> Result<()> {
    if heads == 0 {
        bail!("model '{s}': heads must be positive");
    }
    if d == 0 || d % heads != 0 {
        bail!("model '{s}': d_model {d} is not divisible into {heads} heads");
    }
    Ok(())
}

/// Parse a model string into its layer chain:
///
/// | value | layers |
/// |---|---|
/// | `mlp:<d0>x<d1>x...x<dk>` | `fc<i>: [tokens x d_{i-1}] . [d_{i-1} x d_i]` (k >= 2 dims) |
/// | `block:<d>` | `qkv:<d>, attn-out:<d>, mlp-up:<d>, mlp-down:<d>` |
/// | `transformer:<d>x<h>x<L>` | `L` blocks of `qkv`, `<h>`-head prefill attention, `attn-out`, `mlp-up`, `mlp-down` |
/// | `decode:<d>x<h>x<ctx>` | `qkv`, `<h>`-head decode attention over a `ctx`-deep KV cache, `attn-out` |
/// | `<shape>,<shape>,...` | explicit [`parse_shape`] entries (`conv:` entries keep their geometry) |
///
/// Chaining rule: every layer's reduction width `K` must not exceed the
/// previous layer's output width `N` (`K < N` feeds the leading `K`
/// features — for decode attention after `qkv` that *is* the Q slice;
/// see `docs/THEORY.md`), every layer shares the token dimension `M`,
/// and a `conv:` layer may only come first (the model input is its
/// image).
pub fn parse_model(s: &str, tokens: usize) -> Result<Vec<ModelLayer>> {
    if tokens == 0 {
        bail!("tokens must be positive");
    }
    let gemm = |name: String, shape: GemmShape| ModelLayer {
        name,
        shape,
        kind: LayerKind::Gemm,
        fmts: None,
    };
    let layers: Vec<ModelLayer> = if let Some(arg) = s.strip_prefix("mlp:") {
        let dims: Vec<usize> = arg
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .with_context(|| format!("model '{s}': '{d}' is not a dimension"))
            })
            .collect::<Result<_>>()?;
        if dims.len() < 2 {
            bail!("model '{s}': mlp needs at least two dims, 'mlp:<d0>x<d1>[x...]'");
        }
        dims.windows(2)
            .enumerate()
            .map(|(i, d)| {
                // parse_shape re-validates positivity and the 2^20 bound
                let shape = parse_shape(&format!("gemm:{tokens}x{}x{}", d[0], d[1]), 1)?;
                Ok(gemm(format!("fc{i}"), shape))
            })
            .collect::<Result<_>>()?
    } else if let Some(arg) = s.strip_prefix("block:") {
        ["qkv", "attn-out", "mlp-up", "mlp-down"]
            .iter()
            .map(|kind| {
                let name = format!("{kind}:{arg}");
                let shape = parse_shape(&name, tokens)?;
                Ok(gemm(name, shape))
            })
            .collect::<Result<_>>()?
    } else if let Some(arg) = s.strip_prefix("transformer:") {
        let (d, heads, blocks) = parse_triple(s, arg, "transformer:<d_model>x<heads>x<layers>")?;
        check_heads(s, d, heads)?;
        if blocks == 0 {
            bail!("model '{s}': layer count must be positive");
        }
        let mut layers = Vec::with_capacity(5 * blocks.min(MAX_MODEL_LAYERS));
        for bi in 0..blocks {
            // the projections reuse the named shapes (bounds included);
            // the attention stage consumes the fused QKV output
            for kind in ["qkv", "attn-out", "mlp-up", "mlp-down"] {
                let shape = parse_shape(&format!("{kind}:{d}"), tokens)?;
                if kind == "attn-out" {
                    layers.push(ModelLayer {
                        name: format!("b{bi}.attn"),
                        shape: GemmShape { m: tokens, k: 3 * d, n: d },
                        kind: LayerKind::Attention { heads, ctx: None },
                        fmts: None,
                    });
                }
                layers.push(gemm(format!("b{bi}.{kind}"), shape));
            }
            if layers.len() > MAX_MODEL_LAYERS {
                break; // the shared bound below reports the error
            }
        }
        layers
    } else if let Some(arg) = s.strip_prefix("decode:") {
        let (d, heads, ctx) = parse_triple(s, arg, "decode:<d_model>x<heads>x<ctx>")?;
        check_heads(s, d, heads)?;
        if ctx == 0 {
            bail!("model '{s}': ctx must be positive");
        }
        if ctx > MAX_DIM {
            bail!("model '{s}': ctx must be <= {MAX_DIM}");
        }
        vec![
            gemm("qkv".to_string(), parse_shape(&format!("qkv:{d}"), tokens)?),
            ModelLayer {
                name: "decode-attn".to_string(),
                shape: GemmShape { m: tokens, k: d, n: d },
                kind: LayerKind::Attention { heads, ctx: Some(ctx) },
                fmts: None,
            },
            gemm("attn-out".to_string(), parse_shape(&format!("attn-out:{d}"), tokens)?),
        ]
    } else {
        s.split(',')
            .map(str::trim)
            .filter(|e| !e.is_empty())
            .map(|e| {
                let shape = parse_shape(e, tokens)?;
                let kind = if e.starts_with("conv:") {
                    LayerKind::Conv(ConvShape::parse(e)?)
                } else {
                    LayerKind::Gemm
                };
                Ok(ModelLayer { name: e.to_string(), shape, kind, fmts: None })
            })
            .collect::<Result<_>>()?
    };
    if layers.is_empty() {
        bail!("model '{s}' has no layers");
    }
    if layers.len() > MAX_MODEL_LAYERS {
        bail!("model '{s}' has {} layers (max {MAX_MODEL_LAYERS})", layers.len());
    }
    check_chain(s, &layers)?;
    Ok(layers)
}

/// Validate the chaining rule (shared by [`parse_model`] and the
/// executor, which also accepts hand-built layer lists).
pub fn check_chain(what: &str, layers: &[ModelLayer]) -> Result<()> {
    if layers.is_empty() {
        bail!("model '{what}' has no layers");
    }
    let m = layers[0].shape.m;
    for (i, l) in layers.iter().enumerate() {
        if l.shape.m != m {
            bail!(
                "model '{what}': layer {i} ('{}') has M={} but the chain runs at M={m}",
                l.name,
                l.shape.m
            );
        }
        if i > 0 {
            if matches!(l.kind, LayerKind::Conv(_)) {
                bail!(
                    "model '{what}': layer {i} ('{}') is a conv layer, which may only \
                     come first (the model input is its image)",
                    l.name
                );
            }
            let prev = layers[i - 1].shape.n;
            if l.shape.k > prev {
                bail!(
                    "model '{what}': layer {i} ('{}') needs K={} inputs but layer {} \
                     only produces N={prev}",
                    l.name,
                    l.shape.k,
                    i - 1
                );
            }
        }
    }
    Ok(())
}

/// Statistics of the (scaled) activation tensor feeding one layer — the
/// [`crate::workload::EmpiricalDist`] fit summary of the inter-layer
/// traffic (requested via [`ModelSpec::fit_activations`]).
#[derive(Debug, Clone, Copy)]
pub struct ActStats {
    /// Dynamic range of the nonzero activations, bits.
    pub dr_bits: f64,
    /// Robust core spread ((Q(.84) - Q(.16)) / 2 on the normalized scale).
    pub sigma_core: f64,
    /// Mass beyond the fit's outlier threshold.
    pub outlier_mass: f64,
    /// Mean of the normalized activations.
    pub mean: f64,
    /// Standard deviation of the normalized activations.
    pub std: f64,
}

/// One executed layer of a model: the tile-level report plus the
/// inter-layer bookkeeping that only exists at model scale.
#[derive(Debug, Clone)]
pub struct LayerOutcome {
    /// The tile mapper's per-layer evaluation.
    pub report: LayerReport,
    /// Static per-tensor calibration scale (max |activation|) applied
    /// before requantization.
    pub a_scale: f64,
    /// SQNR of the inter-layer requantization to the input format, dB
    /// (scaled activations vs their format-quantized f32 encoding).
    pub requant_sqnr_db: f64,
    /// SQNR of the post-softmax probability requantization, dB — the
    /// second calibration point that only attention stages have
    /// (`None` for plain GEMM / conv layers).
    pub softmax_requant_db: Option<f64>,
    /// Fit summary of the activations feeding this layer (when
    /// [`ModelSpec::fit_activations`] is set and the fit succeeds).
    pub act_stats: Option<ActStats>,
}

/// The network-level evaluation: per-layer outcomes plus model totals.
/// Produced by [`exec::forward_stages`] / [`exec::run_model`]; rendered
/// by [`ModelReport::to_figure_result`].
#[derive(Debug, Clone)]
pub struct ModelReport {
    /// Model label.
    pub name: String,
    /// Token/batch dimension shared by every layer.
    pub tokens: usize,
    /// Per-layer outcomes, input to output.
    pub layers: Vec<LayerOutcome>,
    /// End-to-end output SQNR vs the exact float chain, dB (NaN on the
    /// no-reference fast path).
    pub sqnr_db: f64,
    /// Float-inference classification accuracy (trained-MLP path only).
    pub accuracy_float: Option<f64>,
    /// CIM-inference classification accuracy (trained-MLP path only).
    pub accuracy_cim: Option<f64>,
}

impl ModelReport {
    /// Total model energy: sum of the per-layer totals, fJ.
    pub fn total_fj(&self) -> f64 {
        self.layers.iter().map(|l| l.report.total_fj()).sum()
    }

    /// Total useful MACs over the chain.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.report.shape.macs()).sum()
    }

    /// Energy per useful MAC, fJ.
    pub fn fj_per_mac(&self) -> f64 {
        self.total_fj() / self.macs() as f64
    }

    /// Energy per operation (one MAC = two ops, the paper's convention).
    pub fn fj_per_op(&self) -> f64 {
        self.fj_per_mac() / 2.0
    }

    /// Energy per generated token, fJ — the decode-phase figure of
    /// merit (total energy over the shared token dimension `M`).
    pub fn fj_per_token(&self) -> f64 {
        self.total_fj() / self.tokens as f64
    }

    /// CIM-minus-float classification-accuracy delta (trained-MLP path).
    pub fn accuracy_delta(&self) -> Option<f64> {
        match (self.accuracy_cim, self.accuracy_float) {
            (Some(c), Some(f)) => Some(c - f),
            _ => None,
        }
    }

    /// ADC-resolution histogram across every tile of every layer:
    /// (floor(ENOB), tile count), ascending.
    pub fn enob_histogram(&self) -> Vec<(i64, usize)> {
        let mut bins = std::collections::BTreeMap::new();
        for l in &self.layers {
            for t in &l.report.tiles {
                *bins.entry(t.enob.floor() as i64).or_insert(0usize) += 1;
            }
        }
        bins.into_iter().collect()
    }

    /// Number of tiles across every layer.
    pub fn tile_count(&self) -> usize {
        self.layers.iter().map(|l| l.report.tiles.len()).sum()
    }

    /// Mean per-tile ADC resolution across the whole model, bits.
    pub fn enob_mean(&self) -> f64 {
        let n = self.tile_count();
        let sum: f64 = self
            .layers
            .iter()
            .flat_map(|l| l.report.tiles.iter().map(|t| t.enob))
            .sum();
        sum / n as f64
    }

    /// Render the report as tables + invariant checks (the `grcim model`
    /// output and the serve layer's `model` response).
    pub fn to_figure_result(&self) -> FigureResult {
        let mut fr = FigureResult::new("model");

        let mut summary = Table::new("model summary", &["metric", "value"]);
        let mut kv = |k: &str, v: String| summary.row(vec![k.into(), v]);
        kv("model", self.name.clone());
        kv("tokens", self.tokens.to_string());
        kv("layers", self.layers.len().to_string());
        kv("tiles", self.tile_count().to_string());
        kv("macs", self.macs().to_string());
        kv("enob_mean", Table::f(self.enob_mean()));
        kv("end_to_end_sqnr_db", Table::f(self.sqnr_db));
        kv("total_fj", Table::f(self.total_fj()));
        kv("fj_per_mac", Table::f(self.fj_per_mac()));
        kv("fj_per_op", Table::f(self.fj_per_op()));
        kv("fj_per_token", Table::f(self.fj_per_token()));
        if let (Some(f), Some(c)) = (self.accuracy_float, self.accuracy_cim) {
            kv("accuracy_float", Table::f(f));
            kv("accuracy_cim", Table::f(c));
            kv("accuracy_delta", Table::f(c - f));
        }
        fr.tables.push(summary);

        let mut layers = Table::new(
            "layers",
            &[
                "layer", "shape", "tiles", "enob_mean", "sqnr_db", "requant_db", "softmax_db",
                "act_dr_bits", "act_outliers", "total_fj", "fj_per_mac",
            ],
        );
        for l in &self.layers {
            let r = &l.report;
            let (dr, mass) = match &l.act_stats {
                Some(s) => (Table::f(s.dr_bits), Table::f(s.outlier_mass)),
                None => ("-".into(), "-".into()),
            };
            let softmax_db = match l.softmax_requant_db {
                Some(v) => Table::f(v),
                None => "-".into(),
            };
            layers.row(vec![
                r.name.clone(),
                r.shape.to_string(),
                r.tiles.len().to_string(),
                Table::f(r.enob_mean()),
                Table::f(r.sqnr_db),
                Table::f(l.requant_sqnr_db),
                softmax_db,
                dr,
                mass,
                Table::f(r.total_fj()),
                Table::f(r.fj_per_mac()),
            ]);
        }
        fr.tables.push(layers);

        let mut hist = Table::new("adc histogram (all layers)", &["enob_bin", "tiles", "pct"]);
        let tiles = self.tile_count();
        for (bin, count) in self.enob_histogram() {
            hist.row(vec![
                format!("[{bin},{})", bin + 1),
                count.to_string(),
                Table::f(100.0 * count as f64 / tiles as f64),
            ]);
        }
        fr.tables.push(hist);

        // ---- invariant checks (distribution-independent) ----
        // model totals must reconcile with independent energy::arch
        // evaluations at the reported per-tile resolutions, layer by layer
        let mut independent = 0.0;
        for l in &self.layers {
            let r = &l.report;
            let mvm_ops = (2 * r.cfg.nr * r.cfg.nc * r.shape.m) as f64;
            let tiles_fj: f64 = r
                .tiles
                .iter()
                .map(|t| {
                    energy_per_op(r.cfg.arch, r.cfg.fmts, r.cfg.nr, r.cfg.nc, t.enob, &r.cfg.tech)
                        .total()
                        * mvm_ops
                })
                .sum();
            independent += tiles_fj + r.reduction_fj + r.global_norm_fj + r.softmax_fj;
        }
        let total = self.total_fj();
        let rel = (independent - total).abs() / total.max(1e-300);
        fr.check(
            "layer energy totals reconcile with energy::arch",
            "sum of independent per-tile evaluations",
            format!("rel diff {rel:.3e}"),
            rel < 1e-9,
        );
        let covered: u64 =
            self.layers.iter().flat_map(|l| l.report.tiles.iter().map(|t| t.macs)).sum();
        fr.check(
            "tile grids cover every layer GEMM exactly once",
            format!("{} macs", self.macs()),
            format!("{covered} macs"),
            covered == self.macs(),
        );
        let enob_ok = self
            .layers
            .iter()
            .flat_map(|l| l.report.tiles.iter())
            .all(|t| t.enob.is_finite() && (0.0..=MAX_TILE_ENOB).contains(&t.enob));
        fr.check(
            "per-tile ADC resolutions are finite and physical",
            format!("0 <= enob <= {MAX_TILE_ENOB}"),
            format!("mean {}", Table::f(self.enob_mean())),
            enob_ok,
        );
        let requant_ok = self.layers.iter().all(|l| l.requant_sqnr_db.is_finite());
        fr.check(
            "model SQNR, requantization SQNRs, and energy totals are finite",
            "finite",
            format!("e2e {} dB, total {} fJ", Table::f(self.sqnr_db), Table::f(total)),
            self.sqnr_db.is_finite() && total.is_finite() && requant_ok,
        );
        fr
    }
}

/// A completed model evaluation: the report plus the network's final
/// activations (row-major `[M][N_last]`, float domain).
#[derive(Debug, Clone)]
pub struct ModelResult {
    /// Per-layer and network-level evaluation.
    pub report: ModelReport,
    /// Final-layer activations after the epilogue, row-major.
    pub y: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_preset_expands_to_a_chain() {
        let layers = parse_model("mlp:24x16x12x8", 4).unwrap();
        assert_eq!(layers.len(), 3);
        assert_eq!(layers[0].shape, GemmShape { m: 4, k: 24, n: 16 });
        assert_eq!(layers[1].shape, GemmShape { m: 4, k: 16, n: 12 });
        assert_eq!(layers[2].shape, GemmShape { m: 4, k: 12, n: 8 });
        assert_eq!(layers[0].name, "fc0");
        assert!(ModelSpec::preset("mlp:24x16x8", 4).unwrap().relu);
    }

    #[test]
    fn block_preset_reuses_named_shapes() {
        let layers = parse_model("block:16", 2).unwrap();
        assert_eq!(layers.len(), 4);
        assert_eq!(layers[0].shape, GemmShape { m: 2, k: 16, n: 48 });
        assert_eq!(layers[1].shape, GemmShape { m: 2, k: 16, n: 16 });
        assert_eq!(layers[2].shape, GemmShape { m: 2, k: 16, n: 64 });
        assert_eq!(layers[3].shape, GemmShape { m: 2, k: 64, n: 16 });
        assert!(!ModelSpec::preset("block:16", 2).unwrap().relu);
    }

    #[test]
    fn transformer_preset_expands_to_attention_blocks() {
        let layers = parse_model("transformer:64x4x2", 4).unwrap();
        assert_eq!(layers.len(), 10);
        for bi in 0..2 {
            let b = &layers[5 * bi..5 * (bi + 1)];
            assert_eq!(b[0].name, format!("b{bi}.qkv"));
            assert_eq!(b[0].shape, GemmShape { m: 4, k: 64, n: 192 });
            assert_eq!(b[1].name, format!("b{bi}.attn"));
            assert_eq!(b[1].shape, GemmShape { m: 4, k: 192, n: 64 });
            assert_eq!(b[1].kind, LayerKind::Attention { heads: 4, ctx: None });
            assert_eq!(b[2].name, format!("b{bi}.attn-out"));
            assert_eq!(b[2].shape, GemmShape { m: 4, k: 64, n: 64 });
            assert_eq!(b[3].shape, GemmShape { m: 4, k: 64, n: 256 });
            assert_eq!(b[4].shape, GemmShape { m: 4, k: 256, n: 64 });
        }
        // prefill attention MACs: 2·M·S·d with S = M
        assert_eq!(layers[1].macs(), 2 * 4 * 4 * 64);
        assert!(!ModelSpec::preset("transformer:64x4x2", 4).unwrap().relu);
        // 1-head degenerate case still parses (distinct from block:)
        let one = parse_model("transformer:64x1x2", 4).unwrap();
        assert_eq!(one[1].kind, LayerKind::Attention { heads: 1, ctx: None });
    }

    #[test]
    fn decode_preset_is_a_kv_cache_gemv_scenario() {
        let layers = parse_model("decode:64x4x128", 1).unwrap();
        assert_eq!(layers.len(), 3);
        assert_eq!(layers[0].shape, GemmShape { m: 1, k: 64, n: 192 });
        assert_eq!(layers[1].name, "decode-attn");
        // decode consumes only the leading Q slice of the fused QKV
        assert_eq!(layers[1].shape, GemmShape { m: 1, k: 64, n: 64 });
        assert_eq!(layers[1].kind, LayerKind::Attention { heads: 4, ctx: Some(128) });
        assert_eq!(layers[2].shape, GemmShape { m: 1, k: 64, n: 64 });
        // decode attention MACs: 2·M·ctx·d
        assert_eq!(layers[1].macs(), 2 * 128 * 64);
    }

    #[test]
    fn malformed_attention_presets_are_clean_errors() {
        for bad in [
            "transformer:64x4",      // missing layer count
            "transformer:64x4x2x1",  // too many dims
            "transformer:64x0x2",    // zero heads
            "transformer:63x4x2",    // d_model not divisible by heads
            "transformer:0x1x2",     // zero d_model
            "transformer:64x4x0",    // zero layers
            "transformer:64x4x999",  // exceeds MAX_MODEL_LAYERS
            "transformer:64xax2",    // non-numeric
            "decode:64x4",           // missing ctx
            "decode:64x0x16",        // zero heads
            "decode:63x4x16",        // d_model not divisible
            "decode:64x4x0",         // zero ctx
            "decode:64x4x2097152",   // ctx beyond MAX_DIM
        ] {
            assert!(parse_model(bad, 4).is_err(), "{bad}");
        }
    }

    #[test]
    fn conv_layers_only_lead_and_kinds_survive_lists() {
        let layers = parse_model("conv:6x3x3x3@8x8,gemm:36x6x4", 1).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].shape, GemmShape { m: 36, k: 27, n: 6 });
        assert!(matches!(layers[0].kind, LayerKind::Conv(cs) if cs.gemm_shape() == layers[0].shape));
        assert_eq!(layers[1].kind, LayerKind::Gemm);
        // conv anywhere but first is rejected
        let err =
            parse_model("gemm:36x8x27, conv:6x3x3x3@8x8", 1).unwrap_err().to_string();
        assert!(err.contains("only"), "{err}");
        // conv slab accounting includes the image
        assert_eq!(
            layers[0].slab_elems(),
            (8 * 8 * 3 + 36 * 27 + 6 * 27 + 36 * 6) as u64
        );
    }

    #[test]
    fn attention_slab_elems_see_the_ctx_squared_blowup() {
        let prefill = parse_model("transformer:64x4x1", 4).unwrap();
        // xq + output + 2·heads·M·S probs, no KV cache for prefill
        assert_eq!(
            prefill[1].slab_elems(),
            (4 * 192 + 4 * 64 + 2 * 4 * 4 * 4) as u64
        );
        let decode = parse_model("decode:64x4x1024", 1).unwrap();
        // Q + output + KV cache (2·ctx·d) + probs (2·heads·M·ctx)
        assert_eq!(
            decode[1].slab_elems(),
            (64 + 64 + 2 * 1024 * 64 + 2 * 4 * 1024) as u64
        );
    }

    #[test]
    fn explicit_lists_chain_and_mischains_are_errors() {
        let layers = parse_model("gemm:2x8x6, gemm:2x6x4", 9).unwrap();
        assert_eq!(layers.len(), 2);
        // K < previous N is the documented truncation, K > N is an error
        assert!(parse_model("gemm:2x8x6,gemm:2x4x4", 9).is_ok());
        let err = parse_model("gemm:2x8x6,gemm:2x7x4", 9).unwrap_err().to_string();
        assert!(err.contains("only produces"), "{err}");
        // mismatched token dimension
        assert!(parse_model("gemm:2x8x6,gemm:3x6x4", 9).is_err());
    }

    #[test]
    fn malformed_models_are_clean_errors() {
        for bad in [
            "mlp:",         // no dims
            "mlp:16",       // one dim
            "mlp:16xabc",   // non-numeric
            "mlp:16x0",     // zero dim
            "block:",       // empty d
            "block:0",      // zero d
            "warp:64",      // unknown shape kind
            "",             // empty list
            ",,",           // empty entries only
            "gemm:2x8",     // bad shape in list
        ] {
            assert!(parse_model(bad, 4).is_err(), "{bad}");
        }
        assert!(parse_model("mlp:16x8", 0).is_err());
        // the layer-count bound holds
        let many = vec!["16"; MAX_MODEL_LAYERS + 2].join("x");
        assert!(parse_model(&format!("mlp:{many}"), 2).is_err());
    }

    #[test]
    fn empty_hand_built_chains_are_errors_not_panics() {
        // ModelSpec fields are public; an empty hand-built layer list
        // must fail cleanly through every entry point
        assert!(check_chain("empty", &[]).is_err());
        let mut spec = ModelSpec::preset("mlp:8x8", 2).unwrap();
        spec.layers.clear();
        let campaign = crate::coordinator::CampaignConfig::default();
        assert!(super::run_model(&spec, &campaign).is_err());
    }

    #[test]
    fn spec_macs_and_layer_cfg_overrides() {
        let mut spec = ModelSpec::preset("mlp:8x8x8", 2).unwrap();
        assert_eq!(spec.macs(), 2 * (2 * 8 * 8) as u64);
        let wide = FormatPair::new(FpFormat::fp(5, 2), FpFormat::fp4_e2m1());
        spec.layers[1].fmts = Some(wide);
        assert_eq!(spec.layer_cfg(0).fmts.x, FpFormat::fp(4, 2));
        assert_eq!(spec.layer_cfg(1).fmts.x, FpFormat::fp(5, 2));
    }
}
