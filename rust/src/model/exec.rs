//! The model executor: layer-by-layer forward pass over GR-MAC tile
//! layers with inter-layer requantization, the float reference chain,
//! and the pooled model runner.
//!
//! Determinism contract: a model run is a pure function of (stages,
//! input, engine) — [`run_model`] additionally pins the operand draws to
//! the campaign seed (stream [`MODEL_STREAM`]), and every layer's tile
//! jobs shard through [`crate::tile::run_layer_with_data`], which
//! re-orders results by tile index — so model results are bit-identical
//! at any worker count (asserted in `rust/tests/properties.rs`).

use super::attn::{attention_reference, run_attention, validate_attn_stage};
use super::{
    check_chain, ActStats, AttnKvCache, AttnSpec, LayerKind, LayerOutcome, ModelLayer,
    ModelReport, ModelResult, ModelSpec,
};
use crate::coordinator::CampaignConfig;
use crate::rng::{job_seed, Pcg64};
use crate::runtime::Engine;
use crate::tile::{
    gemm_outputs, gemm_with_engine, im2col, run_layer_with_data, ConvShape, GemmShape,
    LayerResult, TileConfig,
};
use crate::util::db;
use crate::workload::{EmpiricalDist, TensorTrace};
use anyhow::{bail, Result};

/// Grid-index namespace of the model operand RNG streams in
/// [`crate::rng::job_seed`] — disjoint from campaign spec indices and
/// from the single-layer [`crate::tile::mapper::LAYER_STREAM`], so model
/// operands never collide with either at the same campaign seed. Batch
/// index 0 draws the model input; batch index `li + 1` draws layer
/// `li`'s weights. The Python twin (`tools/gen_goldens.py`) uses the
/// same constants.
pub const MODEL_STREAM: u64 = 0x30DE1;

/// Executor options of [`forward_stages`].
#[derive(Debug, Clone, Copy)]
pub struct ForwardOpts {
    /// Run the float reference chain and per-layer reference GEMMs
    /// (per-layer + end-to-end SQNR). The inference fast path
    /// ([`crate::nn::cim_forward_batch`]) turns this off and every SQNR
    /// is NaN.
    pub with_reference: bool,
    /// Fit an [`EmpiricalDist`] to the scaled activations feeding each
    /// layer and attach its summary to the layer outcome.
    pub fit_activations: bool,
}

/// One executable layer: geometry, array configuration, and its weights
/// (pre-scaled to the array's [-1, 1] full scale, transposed `[N][K]` —
/// the `nn::Dense` layout).
#[derive(Debug, Clone)]
pub struct Stage {
    /// Layer label (reports only).
    pub name: String,
    /// GEMM dimensions.
    pub shape: GemmShape,
    /// Array configuration this layer maps onto.
    pub cfg: TileConfig,
    /// Scaled transposed weights, row-major `[N][K]`.
    pub wt: Vec<f32>,
    /// The static weight scale `wt` was divided by (1.0 for operands
    /// drawn directly in full scale); the epilogue multiplies it back.
    pub w_scale: f64,
    /// Per-output biases, applied in the float domain after rescaling.
    pub bias: Option<Vec<f64>>,
    /// Apply ReLU after this layer's epilogue.
    pub relu: bool,
    /// Attention configuration — set, this stage runs QK^T / softmax /
    /// A·V ([`run_attention`]) instead of one GEMM; `wt` must be empty
    /// and `bias`/`relu` off.
    pub attn: Option<AttnSpec>,
    /// Convolution geometry — set, the stage's input is the HWC image
    /// (`ConvShape::img_elems` values) and the executor [`im2col`]-
    /// expands it after requantization; `shape` must equal its
    /// [`ConvShape::gemm_shape`]. Only valid on the first stage.
    pub conv: Option<ConvShape>,
}

/// How GEMMs execute: sequentially on one engine (the inference path) or
/// sharded across the coordinator worker pool (the campaign path —
/// bit-identical to sequential for any worker count).
#[derive(Clone, Copy)]
pub enum Runner<'a> {
    /// One engine, tiles in index order (each worker-free call reuses
    /// the tile mapper's scratch buffers).
    Sequential(&'a dyn Engine),
    /// Tile jobs shard across the worker pool; the pooled path always
    /// computes the per-layer reference GEMM.
    Pooled(&'a CampaignConfig),
}

impl Runner<'_> {
    pub(crate) fn run(
        &self,
        name: &str,
        cfg: &TileConfig,
        shape: GemmShape,
        x: &[f32],
        wt: &[f32],
        with_reference: bool,
    ) -> Result<LayerResult> {
        match self {
            Runner::Sequential(engine) => {
                if with_reference {
                    gemm_with_engine(*engine, name, cfg, shape, x, wt)
                } else {
                    gemm_outputs(*engine, name, cfg, shape, x, wt)
                }
            }
            Runner::Pooled(campaign) => {
                run_layer_with_data(name, cfg, shape, x.to_vec(), wt.to_vec(), campaign)
            }
        }
    }
}

/// Fit the scaled activations feeding a layer; `None` when the tensor
/// cannot be fitted (fewer than two values, or all-zero — e.g. a fully
/// dead ReLU layer).
fn fit_stats(name: &str, scaled: &[f64]) -> Option<ActStats> {
    let trace = TensorTrace::from_f64(name, vec![scaled.len()], scaled.to_vec()).ok()?;
    let fit = EmpiricalDist::fit(&trace).ok()?;
    Some(ActStats {
        dr_bits: fit.dr_bits(),
        sigma_core: fit.sigma_core(),
        outlier_mass: fit.outlier_mass(),
        mean: fit.mean(),
        std: fit.std(),
    })
}

/// The [`LayerKind`] a stage's `attn`/`conv` fields imply (conv wins so
/// a both-set stage fails [`validate_attn_stage`]'s explicit check, not
/// the chain rule).
fn stage_kind(s: &Stage) -> LayerKind {
    match (&s.conv, &s.attn) {
        (Some(cs), _) => LayerKind::Conv(*cs),
        (None, Some(a)) => {
            LayerKind::Attention { heads: a.heads, ctx: a.kv.as_ref().map(|kv| kv.ctx) }
        }
        (None, None) => LayerKind::Gemm,
    }
}

fn validate_stages(name: &str, stages: &[Stage], x0: &[f64]) -> Result<()> {
    if stages.is_empty() {
        bail!("model '{name}' has no stages");
    }
    let layers: Vec<ModelLayer> = stages
        .iter()
        .map(|s| ModelLayer {
            name: s.name.clone(),
            shape: s.shape,
            kind: stage_kind(s),
            fmts: Some(s.cfg.fmts),
        })
        .collect();
    check_chain(name, &layers)?; // includes the conv-only-first rule
    let first = stages[0].shape;
    let need = match &stages[0].conv {
        Some(cs) => cs.img_elems(),
        None => first.m * first.k,
    };
    if x0.len() != need {
        bail!(
            "model '{name}': input has {} values, first layer {} needs {need}",
            x0.len(),
            first
        );
    }
    for s in stages {
        if let Some(cs) = &s.conv {
            if cs.gemm_shape() != s.shape {
                bail!(
                    "model '{name}': layer '{}': shape {} does not match conv geometry {cs}",
                    s.name,
                    s.shape
                );
            }
        }
        if s.attn.is_some() {
            // attention stages carry no weight slab; geometry, KV-cache
            // sizing, and the no-bias/ReLU rule live with the attn module
            validate_attn_stage(name, s)?;
        } else if s.wt.len() != s.shape.n * s.shape.k {
            bail!(
                "model '{name}': layer '{}' has {} weights, shape {} needs {}",
                s.name,
                s.wt.len(),
                s.shape,
                s.shape.n * s.shape.k
            );
        }
        if let Some(b) = &s.bias {
            if b.len() != s.shape.n {
                bail!(
                    "model '{name}': layer '{}' has {} biases for {} outputs",
                    s.name,
                    b.len(),
                    s.shape.n
                );
            }
        }
    }
    Ok(())
}

/// Run a stage chain end to end.
///
/// Per layer: static per-tensor calibration (`a_scale` = max activation
/// magnitude), **inter-layer requantization** of the scaled activations
/// to the layer's input format (quantize the f32 encoding — idempotent
/// under the array's own input quantization, so this is exactly the
/// digital re-encode a physical inter-layer path performs), the tiled
/// GEMM through `runner`, then the float-domain epilogue (rescale, bias,
/// ReLU). The float reference chain runs the same epilogue over exact
/// float GEMMs of the *unquantized* activations, so [`ModelReport::sqnr_db`]
/// prices requantization + array + ADC error jointly.
///
/// When a layer consumes fewer features than the previous layer produced
/// (`K < N_prev`), the leading `K` features feed it (decode attention
/// after `qkv` reads exactly the Q slice this way; see `docs/THEORY.md`).
///
/// Non-GEMM stage kinds: an attention stage ([`Stage::attn`]) runs
/// QK^T / exact digital softmax / A·V through [`run_attention`] — the
/// softmax is a second calibration point, reported as
/// [`LayerOutcome::softmax_requant_db`] — and a conv first stage
/// ([`Stage::conv`]) requantizes its image *before* [`im2col`]
/// expansion, so each image element is encoded once no matter how many
/// patches replicate it.
pub fn forward_stages(
    runner: &Runner<'_>,
    name: &str,
    stages: &[Stage],
    x0: &[f64],
    opts: ForwardOpts,
) -> Result<ModelResult> {
    validate_stages(name, stages, x0)?;
    let m = stages[0].shape.m;
    let mut acts = x0.to_vec();
    let mut width = stages[0].shape.k;
    let mut ref_acts = if opts.with_reference { Some(x0.to_vec()) } else { None };
    let mut outcomes = Vec::with_capacity(stages.len());

    for st in stages {
        let (k, n) = (st.shape.k, st.shape.n);
        let a_scale = acts.iter().fold(0.0f64, |mx, v| mx.max(v.abs())).max(1e-12);

        // requantize the layer's input to its activation format, tracking
        // the requantization SQNR — the leading K features of every token
        // row, or (conv) the raw image before im2col expansion, so each
        // image element is encoded exactly once
        let fmt = st.cfg.fmts.x;
        let mut scaled =
            if opts.fit_activations { Vec::with_capacity(m * k) } else { Vec::new() };
        let mut sig = 0.0f64;
        let mut err = 0.0f64;
        let mut requant = |s: f64, scaled: &mut Vec<f64>| -> f32 {
            let q = fmt.quantize(s as f32 as f64) as f32;
            sig += s * s;
            let d = q as f64 - s;
            err += d * d;
            if opts.fit_activations {
                scaled.push(s);
            }
            q
        };
        let xq: Vec<f32> = match &st.conv {
            Some(cs) => {
                let imgq: Vec<f32> =
                    acts.iter().map(|v| requant(v / a_scale, &mut scaled)).collect();
                im2col(&imgq, cs)
            }
            None => {
                let mut xq = vec![0.0f32; m * k];
                for mi in 0..m {
                    for ki in 0..k {
                        xq[mi * k + ki] = requant(acts[mi * width + ki] / a_scale, &mut scaled);
                    }
                }
                xq
            }
        };
        drop(requant);
        let requant_sqnr_db = db(sig.max(1e-300) / err.max(1e-300));
        let act_stats =
            if opts.fit_activations { fit_stats(&st.name, &scaled) } else { None };

        let (report, next, softmax_requant_db) = if st.attn.is_some() {
            // attention: QK^T / softmax / A·V; outputs come back already
            // rescaled to the real domain (no bias/ReLU epilogue)
            let out = run_attention(runner, st, &xq, a_scale, opts.with_reference)?;
            (out.report, out.y, Some(out.softmax_requant_db))
        } else {
            let res =
                runner.run(&st.name, &st.cfg, st.shape, &xq, &st.wt, opts.with_reference)?;
            // float-domain epilogue: rescale, bias, ReLU
            let mut next = vec![0.0f64; m * n];
            for mi in 0..m {
                for o in 0..n {
                    let mut v = res.y[mi * n + o] * a_scale * st.w_scale;
                    if let Some(b) = &st.bias {
                        v += b[o];
                    }
                    if st.relu {
                        v = v.max(0.0);
                    }
                    next[mi * n + o] = v;
                }
            }
            (res.report, next, None)
        };

        // exact float chain over the same truncation/epilogue
        if let Some(r) = ref_acts.as_mut() {
            let rn = if st.attn.is_some() {
                attention_reference(st, r, width)
            } else {
                // conv: flatten the f64 reference image through the same
                // im2col as the array path, then the plain GEMM applies
                let rx = st.conv.as_ref().map(|cs| im2col(r, cs));
                let (rin, stride): (&[f64], usize) = match &rx {
                    Some(rx) => (rx, k),
                    None => (r, width),
                };
                let mut rn = vec![0.0f64; m * n];
                for mi in 0..m {
                    for o in 0..n {
                        let mut acc = 0.0f64;
                        for ki in 0..k {
                            acc += rin[mi * stride + ki]
                                * (st.wt[o * k + ki] as f64 * st.w_scale);
                        }
                        if let Some(b) = &st.bias {
                            acc += b[o];
                        }
                        if st.relu {
                            acc = acc.max(0.0);
                        }
                        rn[mi * n + o] = acc;
                    }
                }
                rn
            };
            *r = rn;
        }

        acts = next;
        width = n;
        outcomes.push(LayerOutcome {
            report,
            a_scale,
            requant_sqnr_db,
            softmax_requant_db,
            act_stats,
        });
    }

    let sqnr_db = match &ref_acts {
        Some(r) => {
            let mut sig = 0.0f64;
            let mut err = 0.0f64;
            for (y, rv) in acts.iter().zip(r) {
                sig += rv * rv;
                let d = y - rv;
                err += d * d;
            }
            db(sig.max(1e-300) / err.max(1e-300))
        }
        None => f64::NAN,
    };

    Ok(ModelResult {
        report: ModelReport {
            name: name.to_string(),
            tokens: m,
            layers: outcomes,
            sqnr_db,
            accuracy_float: None,
            accuracy_cim: None,
        },
        y: acts,
    })
}

/// Evaluate a [`ModelSpec`]: draw the model input and every layer's
/// weights deterministically from the campaign seed (stream
/// [`MODEL_STREAM`]), then run the chain with every layer's tile jobs
/// sharded across the worker pool.
///
/// Per-kind operand draws (all from the layer's stream `li + 1`):
/// GEMM/conv layers draw `N·K` weights from `dist_w` (a conv first
/// layer's *input* is its `H·W·Cin` image, drawn from `dist_x` at
/// stream 0 — for a 1x1 kernel that is bit-identical to the flattened
/// GEMM's input draw); attention layers hold no weights, and a decode
/// layer instead draws its KV cache from `dist_x` (all `ctx·d_model`
/// keys, then all values, one RNG).
///
/// The result is a pure function of (spec, campaign.seed,
/// campaign.engine) — the property the serve layer's
/// [`crate::server::proto::model_key`] relies on.
pub fn run_model(spec: &ModelSpec, campaign: &CampaignConfig) -> Result<ModelResult> {
    check_chain(&spec.name, &spec.layers)?;
    let first = &spec.layers[0];
    let mut rng = Pcg64::seeded(job_seed(campaign.seed, MODEL_STREAM, 0));
    let x0_len = match first.kind {
        LayerKind::Conv(cs) => cs.img_elems(),
        _ => first.shape.m * first.shape.k,
    };
    let mut x0f = vec![0.0f32; x0_len];
    spec.dist_x.fill_f32(&mut rng, &mut x0f);
    let x0: Vec<f64> = x0f.iter().map(|&v| v as f64).collect();

    let last = spec.layers.len() - 1;
    let stages: Vec<Stage> = spec
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| {
            let mut rng =
                Pcg64::seeded(job_seed(campaign.seed, MODEL_STREAM, li as u64 + 1));
            let (wt, attn) = match l.kind {
                LayerKind::Attention { heads, ctx } => {
                    let kv = ctx.map(|c| {
                        let d = l.shape.n;
                        let mut kc = vec![0.0f32; c * d];
                        spec.dist_x.fill_f32(&mut rng, &mut kc);
                        let mut vc = vec![0.0f32; c * d];
                        spec.dist_x.fill_f32(&mut rng, &mut vc);
                        AttnKvCache { ctx: c, k: kc, v: vc }
                    });
                    (Vec::new(), Some(AttnSpec { heads, kv }))
                }
                _ => {
                    let mut wt = vec![0.0f32; l.shape.n * l.shape.k];
                    spec.dist_w.fill_f32(&mut rng, &mut wt);
                    (wt, None)
                }
            };
            let conv = match l.kind {
                LayerKind::Conv(cs) => Some(cs),
                _ => None,
            };
            let is_attn = attn.is_some();
            Stage {
                name: l.name.clone(),
                shape: l.shape,
                cfg: spec.layer_cfg(li),
                wt,
                w_scale: 1.0,
                bias: None,
                relu: spec.relu && li < last && !is_attn,
                attn,
                conv,
            }
        })
        .collect();

    forward_stages(
        &Runner::Pooled(campaign),
        &spec.name,
        &stages,
        &x0,
        ForwardOpts { with_reference: true, fit_activations: spec.fit_activations },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::Distribution;
    use crate::energy::{CimArch, TechParams};
    use crate::formats::FpFormat;
    use crate::mac::FormatPair;
    use crate::runtime::{EngineKind, RustEngine};
    use crate::tile::AdcPolicy;

    fn small_spec(model: &str, arch: CimArch) -> ModelSpec {
        let mut spec = ModelSpec::preset(model, 2).unwrap();
        spec.cfg.nr = 8;
        spec.cfg.nc = 4;
        spec.cfg.arch = arch;
        spec.cfg.fmts = FormatPair::new(FpFormat::fp(2, 2), FpFormat::fp4_e2m1());
        spec.fit_activations = true;
        spec
    }

    fn campaign(workers: usize, seed: u64) -> CampaignConfig {
        CampaignConfig { engine: EngineKind::Rust, workers, seed, ..Default::default() }
    }

    #[test]
    fn pooled_model_matches_sequential_bitwise() {
        let spec = small_spec("mlp:16x12x8", CimArch::GrUnit);
        let pooled = run_model(&spec, &campaign(3, 11)).unwrap();

        // sequential reference over the same deterministic operands
        let first = spec.layers[0].shape;
        let mut rng = Pcg64::seeded(job_seed(11, MODEL_STREAM, 0));
        let mut x0f = vec![0.0f32; first.m * first.k];
        spec.dist_x.fill_f32(&mut rng, &mut x0f);
        let x0: Vec<f64> = x0f.iter().map(|&v| v as f64).collect();
        let stages: Vec<Stage> = spec
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| {
                let mut rng = Pcg64::seeded(job_seed(11, MODEL_STREAM, li as u64 + 1));
                let mut wt = vec![0.0f32; l.shape.n * l.shape.k];
                spec.dist_w.fill_f32(&mut rng, &mut wt);
                Stage {
                    name: l.name.clone(),
                    shape: l.shape,
                    cfg: spec.layer_cfg(li),
                    wt,
                    w_scale: 1.0,
                    bias: None,
                    relu: li + 1 < spec.layers.len(),
                    attn: None,
                    conv: None,
                }
            })
            .collect();
        let seq = forward_stages(
            &Runner::Sequential(&RustEngine),
            &spec.name,
            &stages,
            &x0,
            ForwardOpts { with_reference: true, fit_activations: true },
        )
        .unwrap();

        assert_eq!(pooled.y.len(), seq.y.len());
        for (a, b) in pooled.y.iter().zip(&seq.y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(pooled.report.sqnr_db.to_bits(), seq.report.sqnr_db.to_bits());
        for (a, b) in pooled.report.layers.iter().zip(&seq.report.layers) {
            assert_eq!(a.report.tiles_fj.to_bits(), b.report.tiles_fj.to_bits());
            assert_eq!(a.requant_sqnr_db.to_bits(), b.requant_sqnr_db.to_bits());
        }
    }

    #[test]
    fn report_invariants_hold_for_gr_and_conventional() {
        for arch in [CimArch::GrUnit, CimArch::Conventional] {
            let spec = small_spec("mlp:16x12x8", arch);
            let res = run_model(&spec, &campaign(2, 5)).unwrap();
            let fr = res.report.to_figure_result();
            assert!(fr.all_hold(), "{arch:?}: {:#?}", fr.checks);
            assert_eq!(res.report.layers.len(), 2);
            // fit was requested and the activations are fittable
            for l in &res.report.layers {
                assert!(l.act_stats.is_some(), "{}", l.report.name);
            }
            // model totals really are the layer sums
            let sum: f64 = res.report.layers.iter().map(|l| l.report.total_fj()).sum();
            assert_eq!(sum.to_bits(), res.report.total_fj().to_bits());
        }
    }

    #[test]
    fn block_preset_truncates_qkv_into_attn_out() {
        let mut spec = small_spec("block:8", CimArch::GrUnit);
        spec.relu = false;
        let res = run_model(&spec, &campaign(2, 3)).unwrap();
        assert_eq!(res.report.layers.len(), 4);
        // final activations have the block's d_model width
        assert_eq!(res.y.len(), 2 * 8);
        assert!(res.report.sqnr_db.is_finite());
    }

    #[test]
    fn requantization_is_idempotent_on_the_format_grid() {
        // quantizing an already-quantized f32 activation is a no-op —
        // the property that makes the explicit inter-layer requantize
        // semantically equal to what the array's DAC input stage does
        let fmt = FpFormat::fp(3, 2);
        let mut rng = Pcg64::seeded(17);
        for _ in 0..500 {
            let s = rng.uniform_in(-1.5, 1.5);
            let q = fmt.quantize(s as f32 as f64) as f32;
            let qq = fmt.quantize(q as f64) as f32;
            assert_eq!(q.to_bits(), qq.to_bits(), "at {s}");
        }
    }

    #[test]
    fn high_precision_chain_tracks_the_float_chain() {
        let mut spec = small_spec("mlp:12x10x6", CimArch::GrUnit);
        spec.cfg.fmts = FormatPair::new(FpFormat::fp(4, 6), FpFormat::fp(4, 6));
        spec.cfg.adc = AdcPolicy::Fixed(22.0);
        spec.dist_w = Distribution::clipped_gauss4();
        spec.cfg.tech = TechParams::default();
        let res = run_model(&spec, &campaign(2, 9)).unwrap();
        assert!(res.report.sqnr_db > 25.0, "e2e sqnr {}", res.report.sqnr_db);
        for l in &res.report.layers {
            assert!(l.requant_sqnr_db > 25.0, "{} requant", l.report.name);
        }
    }

    #[test]
    fn attention_chains_run_and_their_invariants_hold() {
        for arch in [CimArch::GrUnit, CimArch::Conventional] {
            let mut spec = ModelSpec::preset("transformer:16x2x1", 2).unwrap();
            spec.cfg.nr = 8;
            spec.cfg.nc = 4;
            spec.cfg.arch = arch;
            let res = run_model(&spec, &campaign(2, 21)).unwrap();
            assert_eq!(res.report.layers.len(), 5);
            let attn = &res.report.layers[1];
            // the attention stage reports the second calibration point
            assert!(attn.softmax_requant_db.unwrap().is_finite(), "{arch:?}");
            for (i, l) in res.report.layers.iter().enumerate() {
                assert_eq!(l.softmax_requant_db.is_some(), i == 1, "{}", l.report.name);
            }
            // virtual shape M×(2S)×d with S = M for prefill
            assert_eq!(attn.report.shape, GemmShape { m: 2, k: 4, n: 16 });
            let fr = res.report.to_figure_result();
            assert!(fr.all_hold(), "{arch:?}: {:#?}", fr.checks);
            assert!(res.report.sqnr_db.is_finite());
        }
    }

    #[test]
    fn decode_chains_attend_over_their_kv_cache() {
        let mut spec = ModelSpec::preset("decode:16x2x12", 1).unwrap();
        spec.cfg.nr = 8;
        spec.cfg.nc = 4;
        let res = run_model(&spec, &campaign(2, 33)).unwrap();
        assert_eq!(res.report.layers.len(), 3);
        let attn = &res.report.layers[1];
        // virtual shape M×(2·ctx)×d
        assert_eq!(attn.report.shape, GemmShape { m: 1, k: 24, n: 16 });
        assert_eq!(attn.report.shape.macs(), 2 * 12 * 16);
        assert!(attn.softmax_requant_db.unwrap().is_finite());
        assert!(res.report.fj_per_token().is_finite() && res.report.fj_per_token() > 0.0);
        let fr = res.report.to_figure_result();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
    }

    #[test]
    fn conv_chains_run_from_their_image() {
        let mut spec = ModelSpec::preset("conv:4x2x2x2@5x5,gemm:16x4x3", 1).unwrap();
        spec.cfg.nr = 8;
        spec.cfg.nc = 4;
        let res = run_model(&spec, &campaign(2, 17)).unwrap();
        assert_eq!(res.report.layers.len(), 2);
        assert_eq!(res.report.layers[0].report.shape, GemmShape { m: 16, k: 8, n: 4 });
        assert_eq!(res.y.len(), 16 * 3);
        let fr = res.report.to_figure_result();
        assert!(fr.all_hold(), "{:#?}", fr.checks);
        assert!(res.report.sqnr_db.is_finite());
    }

    #[test]
    fn rejects_bad_attn_stages() {
        let spec = small_spec("mlp:8x8", CimArch::GrUnit);
        let cfgc = spec.layer_cfg(0);
        let mk = |shape: GemmShape, attn: Option<AttnSpec>| Stage {
            name: "a".into(),
            shape,
            cfg: cfgc,
            wt: Vec::new(),
            w_scale: 1.0,
            bias: None,
            relu: false,
            attn,
            conv: None,
        };
        let run = |st: Stage, x0: &[f64]| {
            forward_stages(
                &Runner::Sequential(&RustEngine),
                "t",
                std::slice::from_ref(&st),
                x0,
                ForwardOpts { with_reference: false, fit_activations: false },
            )
        };
        let x0 = vec![0.1f64; 2 * 24];
        // prefill K must be 3·d_model
        let bad_k = mk(
            GemmShape { m: 2, k: 16, n: 8 },
            Some(AttnSpec { heads: 2, kv: None }),
        );
        assert!(run(bad_k, &vec![0.1f64; 2 * 16]).is_err());
        // heads must divide d_model
        let bad_h = mk(
            GemmShape { m: 2, k: 24, n: 8 },
            Some(AttnSpec { heads: 3, kv: None }),
        );
        assert!(run(bad_h, &x0).is_err());
        // attention takes no weight slab
        let mut with_wt = mk(
            GemmShape { m: 2, k: 24, n: 8 },
            Some(AttnSpec { heads: 2, kv: None }),
        );
        with_wt.wt = vec![0.0; 4];
        assert!(run(with_wt, &x0).is_err());
        // decode KV cache must be ctx·d_model per tensor
        let bad_kv = mk(
            GemmShape { m: 2, k: 8, n: 8 },
            Some(AttnSpec {
                heads: 2,
                kv: Some(AttnKvCache { ctx: 4, k: vec![0.0; 31], v: vec![0.0; 32] }),
            }),
        );
        assert!(run(bad_kv, &vec![0.1f64; 2 * 8]).is_err());
        // a well-formed prefill stage passes the same harness
        let ok = mk(
            GemmShape { m: 2, k: 24, n: 8 },
            Some(AttnSpec { heads: 2, kv: None }),
        );
        assert!(run(ok, &x0).is_ok());
    }

    #[test]
    fn conv_stages_reject_mismatched_shapes_and_positions() {
        let spec = small_spec("mlp:8x8", CimArch::GrUnit);
        let cfgc = spec.layer_cfg(0);
        let cs = crate::tile::ConvShape::parse("conv:4x2x2x2@5x5").unwrap();
        let mk = |shape: GemmShape, conv| Stage {
            name: "c".into(),
            shape,
            cfg: cfgc,
            wt: vec![0.0; shape.n * shape.k],
            w_scale: 1.0,
            bias: None,
            relu: false,
            attn: None,
            conv,
        };
        let opts = ForwardOpts { with_reference: false, fit_activations: false };
        // shape must equal the conv's flattened GEMM
        let bad = mk(GemmShape { m: 16, k: 9, n: 4 }, Some(cs));
        let r = forward_stages(
            &Runner::Sequential(&RustEngine),
            "t",
            std::slice::from_ref(&bad),
            &vec![0.1f64; cs.img_elems()],
            opts,
        );
        assert!(r.is_err());
        // conv after the first stage is rejected
        let lead = mk(GemmShape { m: 16, k: 8, n: 8 }, None);
        let trail = mk(cs.gemm_shape(), Some(cs));
        let r = forward_stages(
            &Runner::Sequential(&RustEngine),
            "t",
            &[lead, trail],
            &vec![0.1f64; 16 * 8],
            opts,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_stage_chains() {
        let spec = small_spec("mlp:8x8", CimArch::GrUnit);
        let cfgc = spec.layer_cfg(0);
        let stage = |shape: GemmShape| Stage {
            name: "s".into(),
            shape,
            cfg: cfgc,
            wt: vec![0.0; shape.n * shape.k],
            w_scale: 1.0,
            bias: None,
            relu: false,
            attn: None,
            conv: None,
        };
        let a = stage(GemmShape { m: 2, k: 8, n: 4 });
        // input size mismatch
        let r = forward_stages(
            &Runner::Sequential(&RustEngine),
            "t",
            std::slice::from_ref(&a),
            &[0.0; 7],
            ForwardOpts { with_reference: false, fit_activations: false },
        );
        assert!(r.is_err());
        // chain break: second layer wants more inputs than the first makes
        let b = stage(GemmShape { m: 2, k: 6, n: 2 });
        let r = forward_stages(
            &Runner::Sequential(&RustEngine),
            "t",
            &[a.clone(), b],
            &[0.0; 16],
            ForwardOpts { with_reference: false, fit_activations: false },
        );
        assert!(r.is_err());
        // bad weight slab
        let mut c = a;
        c.wt.pop();
        let r = forward_stages(
            &Runner::Sequential(&RustEngine),
            "t",
            &[c],
            &[0.0; 16],
            ForwardOpts { with_reference: false, fit_activations: false },
        );
        assert!(r.is_err());
    }
}
