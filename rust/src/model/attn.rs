//! The real attention stage — QK^T and A·V as GR-MAC tile GEMMs with an
//! exact digital softmax between them (retires documented substitution 8,
//! the leading-K truncation stand-in; see `docs/THEORY.md`).
//!
//! One attention stage runs, per head `h` of `heads` (head width
//! `d_h = d_model / heads`, score width `S` = tokens for prefill or the
//! KV-cache depth `ctx` for decode):
//!
//! 1. **QK^T** — a `[M×d_h]·[d_h×S]` GEMM on the array (the K matrix is
//!    weight-stationary), digitized like any other tile GEMM;
//! 2. **softmax** — exact digital f32, row-wise max-subtracted
//!    ([`softmax_rows_f32`]): this is the paper's "non-GEMM epilogue" —
//!    it runs at full digital precision, so the analog arrays only ever
//!    see the two GEMMs;
//! 3. **requantization** — the probabilities are a *second* inter-layer
//!    calibration point: one shared scale (max probability over every
//!    head) re-encodes them to the array's input format before they can
//!    drive the A·V DACs, tracked as `softmax_requant_db`;
//! 4. **A·V** — a `[M×S]·[S×d_h]` GEMM (V weight-stationary), rescaled
//!    into the real domain and written to the head's output columns.
//!
//! Prefill (`kv: None`) takes the fused QKV projection output as its
//! input (`K = 3·d_model` columns per token: `[Q|K|V]`) and
//! self-attends (`S = M`). Decode (`kv: Some`) takes the leading
//! `d_model` columns (the Q slice — the chain's leading-K rule) and
//! attends over a frozen KV cache of `ctx` entries; the current token's
//! K/V are not appended (steady-state decode accounting, one token
//! against a long context).
//!
//! The combined [`LayerReport`] concatenates every sub-GEMM's tiles
//! (`kt` = sub-GEMM index, QK^T heads first then A·V heads; `nt` = tile
//! index within the sub-GEMM) under the virtual shape `M×(2S)×d_model`,
//! whose MAC count `2·M·S·d_model` is exactly the attention arithmetic —
//! so the model-level energy-reconciliation and MAC-coverage invariants
//! hold unchanged.

use super::exec::{Runner, Stage};
use crate::tile::{GemmShape, LayerReport};
use crate::util::db;
use anyhow::{bail, Result};

/// The attention configuration of a [`Stage`] (stages without one are
/// plain GEMM layers).
#[derive(Debug, Clone)]
pub struct AttnSpec {
    /// Attention heads (`d_model % heads == 0`).
    pub heads: usize,
    /// Decode-phase KV cache; `None` = prefill self-attention.
    pub kv: Option<AttnKvCache>,
}

/// A frozen decode-phase KV cache: `ctx` cached tokens, full-scale
/// values (the executor rescales queries only).
#[derive(Debug, Clone)]
pub struct AttnKvCache {
    /// Cached context length (the score width S).
    pub ctx: usize,
    /// Cached keys, row-major `[ctx][d_model]`.
    pub k: Vec<f32>,
    /// Cached values, row-major `[ctx][d_model]`.
    pub v: Vec<f32>,
}

/// One executed attention stage.
#[derive(Debug, Clone)]
pub struct AttnOutcome {
    /// Combined report over every sub-GEMM's tiles (virtual shape
    /// `M×(2S)×d_model`).
    pub report: LayerReport,
    /// Real-domain attention outputs, row-major `[M][d_model]`.
    pub y: Vec<f64>,
    /// SQNR of the post-softmax requantization (the second calibration
    /// point), dB.
    pub softmax_requant_db: f64,
}

/// In-place row-wise softmax over `rows.len() / cols` rows of `cols`
/// values: exact digital f32, max-subtracted (`exp` evaluated in f64 on
/// the exactly-representable f32 difference, rounded back — the form
/// the Python twin reproduces bit-for-bit).
pub fn softmax_rows_f32(rows: &mut [f32], cols: usize) {
    assert!(cols > 0 && rows.len() % cols == 0, "rows must be a whole number of columns");
    for row in rows.chunks_mut(cols) {
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = ((*v - mx) as f64).exp() as f32;
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// In-place row-wise f64 softmax (the reference chains).
fn softmax_row_f64(row: &mut [f64]) {
    let mx = row.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f64;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Validate an attention stage's geometry (called from the executor's
/// stage validation).
pub(crate) fn validate_attn_stage(model: &str, st: &Stage) -> Result<()> {
    let Some(spec) = &st.attn else {
        return Ok(());
    };
    let (k, d) = (st.shape.k, st.shape.n);
    if st.conv.is_some() {
        bail!("model '{model}': stage '{}' cannot be both attention and conv", st.name);
    }
    if !st.wt.is_empty() {
        bail!(
            "model '{model}': attention stage '{}' takes no weight slab ({} values given)",
            st.name,
            st.wt.len()
        );
    }
    if st.bias.is_some() || st.relu {
        bail!("model '{model}': attention stage '{}' takes no bias/ReLU epilogue", st.name);
    }
    if spec.heads == 0 || d % spec.heads != 0 {
        bail!(
            "model '{model}': attention stage '{}': d_model {d} is not divisible into {} heads",
            st.name,
            spec.heads
        );
    }
    match &spec.kv {
        None => {
            if k != 3 * d {
                bail!(
                    "model '{model}': prefill attention stage '{}' consumes the fused QKV \
                     output, so K must be 3*d_model (got K={k}, d_model={d})",
                    st.name
                );
            }
        }
        Some(kv) => {
            if k != d {
                bail!(
                    "model '{model}': decode attention stage '{}' consumes the Q slice, \
                     so K must equal d_model (got K={k}, d_model={d})",
                    st.name
                );
            }
            if kv.ctx == 0 {
                bail!("model '{model}': decode attention stage '{}': ctx must be positive", st.name);
            }
            if kv.k.len() != kv.ctx * d || kv.v.len() != kv.ctx * d {
                bail!(
                    "model '{model}': decode attention stage '{}': KV cache needs {} values \
                     per tensor (ctx {} x d_model {d}), got K={} V={}",
                    st.name,
                    kv.ctx * d,
                    kv.ctx,
                    kv.k.len(),
                    kv.v.len()
                );
            }
        }
    }
    Ok(())
}

/// Run one attention stage over the requantized inputs `xq` (row-major
/// `[M][K]`, the stage's first calibration at scale `a_scale`). Every
/// sub-GEMM routes through `runner` like any other layer, so attention
/// results are bit-identical at any worker count.
pub fn run_attention(
    runner: &Runner<'_>,
    st: &Stage,
    xq: &[f32],
    a_scale: f64,
    with_reference: bool,
) -> Result<AttnOutcome> {
    let spec = st.attn.as_ref().expect("run_attention needs an attention stage");
    let (m, k_in, d) = (st.shape.m, st.shape.k, st.shape.n);
    let heads = spec.heads;
    let dh = d / heads;
    // prefill reads K/V out of the fused [Q|K|V] input (both carry the
    // stage's activation scale); decode reads the full-scale KV cache
    let (s_len, k_scale, v_scale) = match &spec.kv {
        None => (m, a_scale, a_scale),
        Some(kv) => (kv.ctx, 1.0, 1.0),
    };
    let sqrt_dh = (dh as f64).sqrt();

    // ---- phase A: QK^T per head (K weight-stationary), then softmax ----
    let mut sub_reports: Vec<LayerReport> = Vec::with_capacity(2 * heads);
    let mut probs = vec![0.0f32; heads * m * s_len];
    for h in 0..heads {
        let c0 = h * dh;
        let mut q = vec![0.0f32; m * dh];
        for mi in 0..m {
            for c in 0..dh {
                q[mi * dh + c] = xq[mi * k_in + c0 + c];
            }
        }
        let mut kt = vec![0.0f32; s_len * dh];
        match &spec.kv {
            None => {
                for j in 0..s_len {
                    for c in 0..dh {
                        kt[j * dh + c] = xq[j * k_in + d + c0 + c];
                    }
                }
            }
            Some(kv) => {
                for j in 0..s_len {
                    for c in 0..dh {
                        kt[j * dh + c] = kv.k[j * d + c0 + c];
                    }
                }
            }
        }
        let shape = GemmShape { m, k: dh, n: s_len };
        let res =
            runner.run(&format!("{}.qk{h}", st.name), &st.cfg, shape, &q, &kt, with_reference)?;
        // real-scale scores, cast to the digital f32 softmax domain
        let base = h * m * s_len;
        for (i, y) in res.y.iter().enumerate() {
            probs[base + i] = (y * a_scale * k_scale / sqrt_dh) as f32;
        }
        softmax_rows_f32(&mut probs[base..base + m * s_len], s_len);
        sub_reports.push(res.report);
    }

    // ---- second calibration point: requantize the probabilities ----
    // one shared scale across every head, mirroring the executor's
    // per-tensor (not per-row) calibration convention
    let mut a2 = 0.0f64;
    for &p in &probs {
        a2 = a2.max(p as f64);
    }
    let a2_scale = a2.max(1e-12);
    let fmt = st.cfg.fmts.x;
    let mut pq = vec![0.0f32; probs.len()];
    let mut sig = 0.0f64;
    let mut err = 0.0f64;
    for (slot, &p) in pq.iter_mut().zip(&probs) {
        let s = p as f64 / a2_scale;
        let q = fmt.quantize(s as f32 as f64) as f32;
        *slot = q;
        sig += s * s;
        let e = q as f64 - s;
        err += e * e;
    }
    let softmax_requant_db = db(sig.max(1e-300) / err.max(1e-300));

    // ---- phase B: A·V per head (V weight-stationary) ----
    let mut y_out = vec![0.0f64; m * d];
    for h in 0..heads {
        let c0 = h * dh;
        let mut vt = vec![0.0f32; dh * s_len];
        match &spec.kv {
            None => {
                for o in 0..dh {
                    for j in 0..s_len {
                        vt[o * s_len + j] = xq[j * k_in + 2 * d + c0 + o];
                    }
                }
            }
            Some(kv) => {
                for o in 0..dh {
                    for j in 0..s_len {
                        vt[o * s_len + j] = kv.v[j * d + c0 + o];
                    }
                }
            }
        }
        let base = h * m * s_len;
        let shape = GemmShape { m, k: s_len, n: dh };
        let res = runner.run(
            &format!("{}.av{h}", st.name),
            &st.cfg,
            shape,
            &pq[base..base + m * s_len],
            &vt,
            with_reference,
        )?;
        for mi in 0..m {
            for o in 0..dh {
                y_out[mi * d + c0 + o] = res.y[mi * dh + o] * a2_scale * v_scale;
            }
        }
        sub_reports.push(res.report);
    }

    // ---- stage SQNR: exact f64 attention over the same quantized
    // operands (scores, softmax, and A·V at full precision, no ADC, no
    // probability requantization) ----
    let sqnr_db = if with_reference {
        let mut sig = 0.0f64;
        let mut err = 0.0f64;
        let mut sc = vec![0.0f64; s_len];
        for h in 0..heads {
            let c0 = h * dh;
            for mi in 0..m {
                for (j, slot) in sc.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for c in 0..dh {
                        let kvq = match &spec.kv {
                            None => xq[j * k_in + d + c0 + c],
                            Some(kv) => kv.k[j * d + c0 + c],
                        };
                        acc += xq[mi * k_in + c0 + c] as f64 * kvq as f64;
                    }
                    *slot = acc * a_scale * k_scale / sqrt_dh;
                }
                softmax_row_f64(&mut sc);
                for o in 0..dh {
                    let mut acc = 0.0f64;
                    for (j, p) in sc.iter().enumerate() {
                        let vvq = match &spec.kv {
                            None => xq[j * k_in + 2 * d + c0 + o],
                            Some(kv) => kv.v[j * d + c0 + o],
                        };
                        acc += p * (vvq as f64 * v_scale);
                    }
                    sig += acc * acc;
                    let dlt = y_out[mi * d + c0 + o] - acc;
                    err += dlt * dlt;
                }
            }
        }
        db(sig.max(1e-300) / err.max(1e-300))
    } else {
        f64::NAN
    };

    // ---- combined report: concatenate sub-GEMM tiles under the
    // virtual M×(2S)×d shape (kt = sub-GEMM, nt = tile within it) ----
    let mut tiles = Vec::new();
    let mut tiles_fj = 0.0f64;
    let mut reduction_fj = 0.0f64;
    let mut global_norm_fj = 0.0f64;
    let mut max_sub_tiles = 0usize;
    for (g, r) in sub_reports.iter().enumerate() {
        max_sub_tiles = max_sub_tiles.max(r.tiles.len());
        for (i, t) in r.tiles.iter().enumerate() {
            let mut t = *t;
            t.kt = g;
            t.nt = i;
            tiles.push(t);
        }
        tiles_fj += r.tiles_fj;
        reduction_fj += r.reduction_fj;
        global_norm_fj += r.global_norm_fj;
    }
    // digital softmax: one exp + normalize + register per probability
    // element (heads · M · S of them), priced by the Table II/III-derived
    // per-element term — the cost PR 8 left at zero
    let softmax_fj = (heads * m * s_len) as f64 * st.cfg.tech.e_softmax_fj;
    let report = LayerReport {
        name: st.name.clone(),
        shape: GemmShape { m, k: 2 * s_len, n: d },
        cfg: st.cfg,
        row_tiles: 2 * heads,
        col_tiles: max_sub_tiles,
        tiles,
        tiles_fj,
        reduction_fj,
        global_norm_fj,
        softmax_fj,
        sqnr_db,
    };
    Ok(AttnOutcome { report, y: y_out, softmax_requant_db })
}

/// The float reference of one attention stage: exact f64 attention over
/// the *unquantized* reference activations `r` (row-major `[M][width]`,
/// leading-K rule applied) and the raw KV cache — the reference chain's
/// counterpart of [`run_attention`].
pub(crate) fn attention_reference(st: &Stage, r: &[f64], width: usize) -> Vec<f64> {
    let spec = st.attn.as_ref().expect("attention_reference needs an attention stage");
    let (m, d) = (st.shape.m, st.shape.n);
    let heads = spec.heads;
    let dh = d / heads;
    let s_len = spec.kv.as_ref().map_or(m, |kv| kv.ctx);
    let sqrt_dh = (dh as f64).sqrt();
    let mut out = vec![0.0f64; m * d];
    let mut sc = vec![0.0f64; s_len];
    for h in 0..heads {
        let c0 = h * dh;
        for mi in 0..m {
            for (j, slot) in sc.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for c in 0..dh {
                    let kv = match &spec.kv {
                        None => r[j * width + d + c0 + c],
                        Some(cache) => cache.k[j * d + c0 + c] as f64,
                    };
                    acc += r[mi * width + c0 + c] * kv;
                }
                *slot = acc / sqrt_dh;
            }
            softmax_row_f64(&mut sc);
            for o in 0..dh {
                let mut acc = 0.0f64;
                for (j, p) in sc.iter().enumerate() {
                    let vv = match &spec.kv {
                        None => r[j * width + 2 * d + c0 + o],
                        Some(cache) => cache.v[j * d + c0 + o] as f64,
                    };
                    acc += p * vv;
                }
                out[mi * d + c0 + o] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one_and_peak_at_the_max() {
        let mut rows = vec![0.5f32, 1.5, -0.25, 2.0, /* row 2 */ 3.0, 3.0, 3.0, 3.0];
        softmax_rows_f32(&mut rows, 4);
        for row in rows.chunks(4) {
            let sum: f64 = row.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        }
        // the max score takes the largest probability
        let mx = rows[..4].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(rows[3], mx);
        // a constant row is exactly uniform (exp(0) = 1 for every entry)
        for &p in &rows[4..] {
            assert_eq!(p, 0.25);
        }
    }

    #[test]
    fn softmax_is_shift_invariant() {
        // max subtraction makes the f32 softmax exactly shift-invariant
        // for shifts that keep every difference identical
        let base = [0.5f32, -1.0, 2.0, 0.0];
        let mut a: Vec<f32> = base.to_vec();
        let mut b: Vec<f32> = base.iter().map(|v| v + 4.0).collect();
        softmax_rows_f32(&mut a, 4);
        softmax_rows_f32(&mut b, 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn f64_softmax_normalizes() {
        let mut row = vec![0.1f64, -3.0, 1.25];
        softmax_row_f64(&mut row);
        let sum: f64 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(row.iter().all(|&p| p > 0.0));
    }
}
