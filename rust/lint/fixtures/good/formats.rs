//! Near-miss fixture: `.unwrap()` outside the serving layers
//! (`server/`, `coordinator/`, `explore/`) is not rule U's business —
//! pure-math modules may still panic on internal invariants.

/// Largest finite value of a tiny format table.
pub fn max_finite(table: &[f64]) -> f64 {
    *table.iter().filter(|v| v.is_finite()).next_back().unwrap()
}

/// `env::current_dir` is allowed everywhere (a location, not an input).
pub fn here() -> std::path::PathBuf {
    std::env::current_dir().unwrap()
}
