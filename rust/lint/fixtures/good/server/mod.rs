//! Near-miss fixture: `server/mod.rs` is the one place the service cap
//! literals may be spelled — both spellings must pass here (rule C).

/// MAC budget per layer-scale request.
pub const MAX_LAYER_MACS: u64 = 1 << 36;
/// Operand-slab element budget, spelled in decimal on purpose.
pub const MAX_LAYER_ELEMS: u64 = 134217728;

/// A second decimal spelling of the MAC cap, still in its home file.
pub fn mac_cap_decimal() -> u64 {
    68719476736
}
