//! Near-miss fixture: a Handler impl whose `plan` calls its cap gate
//! (rule H passes), plus `.unwrap()` confined to `#[cfg(test)]` code
//! (rule U passes).

trait Handler {
    fn plan(&mut self) -> Result<String, String>;
}

fn check_samples(samples: usize) -> Result<(), String> {
    if samples == 0 {
        return Err("samples must be positive".to_string());
    }
    Ok(())
}

struct GoodHandler {
    samples: usize,
}

impl Handler for GoodHandler {
    fn plan(&mut self) -> Result<String, String> {
        check_samples(self.samples)?;
        Ok(format!("key:{}", self.samples))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_is_fine_in_tests() {
        let mut h = GoodHandler { samples: 4 };
        assert_eq!(h.plan().unwrap(), "key:4");
    }
}
