//! Near-miss fixture: `main.rs` may read the clock and the environment
//! (rule D passes), and `env::temp_dir` is allowed anywhere — it names
//! a location, not an input.

fn main() {
    let _started = std::time::SystemTime::now();
    let _args: Vec<String> = std::env::args().collect();
    let _tmp = std::env::temp_dir();
}
