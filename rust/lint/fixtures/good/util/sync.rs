//! Near-miss fixture: `util/sync.rs` is the one file allowed to touch
//! `std::sync` directly (rule S passes here and only here).

pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Recover a poisoned lock; the value is still valid.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
