//! Near-miss fixture: the CLI layer is the sanctioned home for argv
//! and environment reads (rule D passes under `cli/`).

/// Collect the program's arguments.
pub fn argv() -> Vec<String> {
    std::env::args().skip(1).collect()
}

/// Read an environment override.
pub fn artifacts_override() -> Option<String> {
    std::env::var("GRCIM_ARTIFACTS").ok()
}
