// expect: D
//! Failing fixture: wall-clock reads in compute code break
//! bit-identical caching and resume.

pub fn stamp() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
