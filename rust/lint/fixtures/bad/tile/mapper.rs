// expect: S
//! Failing fixture: importing `std::sync` outside `util/sync.rs`
//! bypasses the loom-checkable shim.

use std::sync::{Arc, Mutex};

pub fn shared_counter() -> Arc<Mutex<u64>> {
    Arc::new(Mutex::new(0))
}
