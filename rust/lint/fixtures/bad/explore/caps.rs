// expect: C
//! Failing fixture: respelling the service cap literals outside
//! `server/mod.rs` silently forks the cap.

/// The MAC cap, respelled as a shift.
pub fn mac_cap() -> u64 {
    1 << 36
}

/// The slab cap, respelled in decimal.
pub fn slab_cap() -> u64 {
    134217728
}
