// expect: D
//! Failing fixture: an environment read outside `main.rs`/`cli/` makes
//! results depend on more than the spec and the seed.

pub fn artifacts_dir() -> Option<String> {
    std::env::var("GRCIM_ARTIFACTS").ok()
}
