// expect: S
//! Failing fixture: a fully-qualified `std::sync` path is the same
//! shim bypass as an import.

pub fn flag() -> std::sync::atomic::AtomicBool {
    std::sync::atomic::AtomicBool::new(false)
}
