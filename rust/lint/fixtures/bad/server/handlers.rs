// expect: H
//! Failing fixture: a Handler impl whose `plan` never calls a cap gate
//! — the uniform-caps contract of the dispatch pipeline is broken.

trait Handler {
    fn plan(&mut self) -> Result<String, String>;
}

struct UncappedHandler {
    samples: usize,
}

impl Handler for UncappedHandler {
    fn plan(&mut self) -> Result<String, String> {
        // no check_samples/check_layer_caps/check_model_caps call
        Ok(format!("key:{}", self.samples))
    }
}
