// expect: U
//! Failing fixture: `.expect()` in non-test serving-layer code.

/// Index of a kind in a lookup table.
pub fn kind_index(kinds: &[&str], kind: &str) -> usize {
    kinds.iter().position(|k| *k == kind).expect("kind in table")
}
