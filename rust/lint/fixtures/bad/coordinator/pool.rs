// expect: U
//! Failing fixture: `.unwrap()` in non-test coordinator code.

pub fn first_job(jobs: &[u64]) -> u64 {
    *jobs.first().unwrap()
}
