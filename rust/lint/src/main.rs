//! `grcim-lint` — the repo-specific lint gate, run blocking in CI.
//!
//! Five AST-level rules encode invariants of this codebase that
//! rustc/clippy cannot express, each anchored to a real regression
//! class:
//!
//! * **U** — no `.unwrap()`/`.expect()` outside `#[cfg(test)]` code in
//!   `server/`, `coordinator/`, `explore/`: these layers serve network
//!   requests and long campaigns, where a panic poisons locks and
//!   cascades (the pool's panic-safety machinery exists because of
//!   exactly this).
//! * **S** — no `std::sync` outside `util/sync.rs` (tests exempt): every
//!   lock/atomic must come from the [`crate::util::sync`]-style shim so
//!   the loom lane model-checks the real code, and so every lock obeys
//!   the one poisoning-recovery policy.
//! * **C** — the service cap values (`1 << 36` MACs, `1 << 27` slab
//!   elements) may be *defined* only in `server/mod.rs`: a second
//!   spelling of the literal silently forks the cap.
//! * **H** — every `impl Handler` `plan()` in `handlers.rs` must call a
//!   cap gate (`check_samples`/`check_layer_caps`/`check_model_caps`):
//!   the unified-dispatch refactor exists so resource caps apply
//!   uniformly; a new handler that skips its gate reopens the
//!   OOM-a-worker hole the caps closed.
//! * **D** — no wall-clock or environment reads (`SystemTime::now`,
//!   `env::var`/`vars`/`var_os`/`args`) outside `main.rs`, `cli/`, and
//!   `server/metrics.rs`: campaign results must be a function of the
//!   spec and seed alone (bit-identical caches, resumable checkpoints).
//!   `env::temp_dir`/`current_dir` stay allowed — they name locations,
//!   not inputs.
//!
//! Findings can be suppressed only through `allow.list` entries of the
//! form `rule|path-suffix|message-substring|justification` — one entry
//! per site, justification mandatory, unused entries are themselves
//! errors (so the allowlist can never rot ahead of the code).
//!
//! `--selftest` runs every rule against `fixtures/good` (must be clean)
//! and `fixtures/bad` (every `// expect: X` annotation must fire), so
//! the gate is itself gated.

use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use syn::spanned::Spanned;
use syn::visit::Visit;

/// One rule violation at a source location.
#[derive(Debug, Clone)]
struct Finding {
    rule: char,
    /// Path relative to the scanned root (e.g. `server/proto.rs`).
    file: String,
    line: usize,
    msg: String,
}

/// One `allow.list` entry: `rule|path-suffix|message-substring|why`.
struct Allow {
    rule: char,
    path: String,
    contains: String,
    justification: String,
    used: std::cell::Cell<bool>,
}

fn parse_allowlist(path: &Path) -> Result<Vec<Allow>> {
    let mut out = Vec::new();
    if !path.exists() {
        return Ok(out);
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(4, '|');
        let (Some(rule), Some(p), Some(c), Some(j)) =
            (parts.next(), parts.next(), parts.next(), parts.next())
        else {
            bail!("allow.list:{}: want rule|path|contains|justification", i + 1);
        };
        let rule = rule.trim();
        if rule.len() != 1 {
            bail!("allow.list:{}: rule must be one letter, got {rule:?}", i + 1);
        }
        if j.trim().is_empty() {
            bail!("allow.list:{}: a justification is mandatory", i + 1);
        }
        out.push(Allow {
            rule: rule.chars().next().unwrap_or('?'),
            path: p.trim().to_string(),
            contains: c.trim().to_string(),
            justification: j.trim().to_string(),
            used: std::cell::Cell::new(false),
        });
    }
    Ok(out)
}

/// Whether any attribute marks this item as test-only: `#[test]`,
/// `#[cfg(test)]`, or any `cfg(...)` mentioning `test` (e.g.
/// `#[cfg(all(test, not(loom)))]`).
fn is_test_gated(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        let path = a.path();
        if path.segments.last().is_some_and(|s| s.ident == "test") {
            return true;
        }
        if path.is_ident("cfg") {
            if let syn::Meta::List(l) = &a.meta {
                let toks = l.tokens.to_string();
                // token-level, so `mod tests` bodies and strings don't
                // fool it; `testing` etc. would, but no cfg here uses it
                return toks.split(|ch: char| !ch.is_alphanumeric() && ch != '_')
                    .any(|w| w == "test");
            }
        }
        false
    })
}

/// Does this `use` tree import anything under `std::sync`?
fn use_tree_hits_std_sync(tree: &syn::UseTree) -> bool {
    fn head_is_sync(tree: &syn::UseTree) -> bool {
        match tree {
            syn::UseTree::Path(p) => p.ident == "sync",
            syn::UseTree::Name(n) => n.ident == "sync",
            syn::UseTree::Rename(r) => r.ident == "sync",
            syn::UseTree::Group(g) => g.items.iter().any(head_is_sync),
            syn::UseTree::Glob(_) => false,
        }
    }
    match tree {
        syn::UseTree::Path(p) if p.ident == "std" => head_is_sync(&p.tree),
        syn::UseTree::Group(g) => g.items.iter().any(use_tree_hits_std_sync),
        _ => false,
    }
}

/// Finds calls to any of the handler cap gates inside a `plan` body.
struct GateFinder {
    found: bool,
}

impl<'ast> Visit<'ast> for GateFinder {
    fn visit_expr_call(&mut self, node: &'ast syn::ExprCall) {
        if let syn::Expr::Path(p) = &*node.func {
            if p.path.segments.last().is_some_and(|s| {
                let id = s.ident.to_string();
                matches!(
                    id.as_str(),
                    "check_samples" | "check_layer_caps" | "check_model_caps"
                )
            }) {
                self.found = true;
            }
        }
        syn::visit::visit_expr_call(self, node);
    }
}

/// The per-file rule walker.
struct Linter<'a> {
    /// Root-relative path of the file being walked.
    rel: String,
    /// The file's source lines (findings echo the offending line so
    /// allowlist `contains` patterns have something stable to match).
    lines: &'a [&'a str],
    findings: &'a mut Vec<Finding>,
}

impl Linter<'_> {
    fn src_line(&self, line: usize) -> &str {
        self.lines.get(line.saturating_sub(1)).map_or("", |l| l.trim())
    }

    fn push(&mut self, rule: char, line: usize, what: &str) {
        let msg = format!("{what}: `{}`", self.src_line(line));
        self.findings.push(Finding { rule, file: self.rel.clone(), line, msg });
    }

    fn in_unwrap_scope(&self) -> bool {
        ["server/", "coordinator/", "explore/"]
            .iter()
            .any(|p| self.rel.starts_with(p))
    }

    fn is_cap_home(&self) -> bool {
        self.rel == "server/mod.rs"
    }

    fn is_sync_shim(&self) -> bool {
        self.rel.ends_with("util/sync.rs")
    }

    fn nondet_exempt(&self) -> bool {
        self.rel == "main.rs"
            || self.rel.starts_with("cli/")
            || self.rel == "server/metrics.rs"
    }

    /// Rule-D check over one path expression's segments.
    fn check_nondet_path(&mut self, path: &syn::Path) {
        if self.nondet_exempt() {
            return;
        }
        let segs: Vec<String> =
            path.segments.iter().map(|s| s.ident.to_string()).collect();
        for w in segs.windows(2) {
            let hit = matches!(
                (w[0].as_str(), w[1].as_str()),
                ("env", "var" | "vars" | "var_os" | "vars_os" | "args" | "args_os")
                    | ("SystemTime", "now")
            );
            if hit {
                self.push(
                    'D',
                    path.span().start().line,
                    &format!(
                        "nondeterministic input `{}::{}` outside main.rs/cli//metrics.rs \
                         (results must be functions of spec + seed)",
                        w[0], w[1]
                    ),
                );
            }
        }
    }
}

impl<'ast> Visit<'ast> for Linter<'_> {
    fn visit_item_mod(&mut self, node: &'ast syn::ItemMod) {
        if is_test_gated(&node.attrs) {
            return; // test-only subtree: every rule exempts it
        }
        syn::visit::visit_item_mod(self, node);
    }

    fn visit_item_fn(&mut self, node: &'ast syn::ItemFn) {
        if is_test_gated(&node.attrs) {
            return;
        }
        syn::visit::visit_item_fn(self, node);
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        if is_test_gated(&node.attrs) {
            return;
        }
        // rule H: a Handler impl's plan() must call a cap gate
        if self.rel.ends_with("handlers.rs") {
            let is_handler_impl = node
                .trait_
                .as_ref()
                .is_some_and(|(_, p, _)| {
                    p.segments.last().is_some_and(|s| s.ident == "Handler")
                });
            if is_handler_impl {
                let plan = node.items.iter().find_map(|i| match i {
                    syn::ImplItem::Fn(f) if f.sig.ident == "plan" => Some(f),
                    _ => None,
                });
                if let Some(plan) = plan {
                    let mut gates = GateFinder { found: false };
                    gates.visit_block(&plan.block);
                    if !gates.found {
                        let ty = match &*node.self_ty {
                            syn::Type::Path(p) => p
                                .path
                                .segments
                                .last()
                                .map(|s| s.ident.to_string())
                                .unwrap_or_default(),
                            _ => String::from("<impl>"),
                        };
                        let line = node.span().start().line;
                        self.findings.push(Finding {
                            rule: 'H',
                            file: self.rel.clone(),
                            line,
                            msg: format!(
                                "plan() of `{ty}` calls no cap gate \
                                 (check_samples/check_layer_caps/check_model_caps)"
                            ),
                        });
                    }
                }
            }
        }
        syn::visit::visit_item_impl(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        if self.in_unwrap_scope() {
            let m = node.method.to_string();
            if m == "unwrap" || m == "expect" {
                self.push(
                    'U',
                    node.method.span().start().line,
                    &format!(
                        "`.{m}()` outside test code in a serving layer \
                         (a panic here poisons locks and cascades)"
                    ),
                );
            }
        }
        syn::visit::visit_expr_method_call(self, node);
    }

    fn visit_item_use(&mut self, node: &'ast syn::ItemUse) {
        if !self.is_sync_shim() && use_tree_hits_std_sync(&node.tree) {
            self.push(
                'S',
                node.span().start().line,
                "std::sync outside util/sync.rs \
                 (use the loom-checkable shim: crate::util::sync)",
            );
        }
        syn::visit::visit_item_use(self, node);
    }

    fn visit_path(&mut self, node: &'ast syn::Path) {
        if !self.is_sync_shim() {
            let mut it = node.segments.iter();
            if let (Some(a), Some(b)) = (it.next(), it.next()) {
                if a.ident == "std" && b.ident == "sync" {
                    self.push(
                        'S',
                        node.span().start().line,
                        "std::sync outside util/sync.rs \
                         (use the loom-checkable shim: crate::util::sync)",
                    );
                }
            }
        }
        self.check_nondet_path(node);
        syn::visit::visit_path(self, node);
    }

    fn visit_expr_lit(&mut self, node: &'ast syn::ExprLit) {
        if !self.is_cap_home() {
            if let syn::Lit::Int(i) = &node.lit {
                if let Ok(v) = i.base10_parse::<u128>() {
                    if v == (1u128 << 36) || v == (1u128 << 27) {
                        self.push(
                            'C',
                            node.span().start().line,
                            "service cap literal respelled outside server/mod.rs \
                             (import MAX_LAYER_MACS/MAX_LAYER_ELEMS instead)",
                        );
                    }
                }
            }
        }
        syn::visit::visit_expr_lit(self, node);
    }

    fn visit_expr_binary(&mut self, node: &'ast syn::ExprBinary) {
        if !self.is_cap_home() {
            if let syn::BinOp::Shl(_) = node.op {
                let lit_val = |e: &syn::Expr| -> Option<u128> {
                    if let syn::Expr::Lit(l) = e {
                        if let syn::Lit::Int(i) = &l.lit {
                            return i.base10_parse::<u128>().ok();
                        }
                    }
                    None
                };
                if lit_val(&node.left) == Some(1)
                    && matches!(lit_val(&node.right), Some(36) | Some(27))
                {
                    self.push(
                        'C',
                        node.span().start().line,
                        "service cap literal respelled outside server/mod.rs \
                         (import MAX_LAYER_MACS/MAX_LAYER_ELEMS instead)",
                    );
                }
            }
        }
        syn::visit::visit_expr_binary(self, node);
    }
}

/// Recursively collect `.rs` files under `root`, sorted for stable output.
fn rust_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("reading {}", dir.display()))?;
        for e in entries {
            let p = e?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Run every rule over one file; `rel` is the root-relative path the
/// path-scoped rules key on.
fn lint_file(rel: &str, source: &str, findings: &mut Vec<Finding>) {
    let ast = match syn::parse_file(source) {
        Ok(ast) => ast,
        Err(e) => {
            // unparseable code can't be checked; fail loudly rather
            // than silently passing the gate
            findings.push(Finding {
                rule: 'P',
                file: rel.to_string(),
                line: e.span().start().line,
                msg: format!("file does not parse: {e}"),
            });
            return;
        }
    };
    let lines: Vec<&str> = source.lines().collect();
    let mut linter = Linter { rel: rel.to_string(), lines: &lines, findings };
    linter.visit_file(&ast);
}

/// Lint every `.rs` file under `root`; paths in findings are relative
/// to `root`.
fn lint_tree(root: &Path) -> Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for path in rust_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        lint_file(&rel, &src, &mut findings);
    }
    Ok(findings)
}

/// Split findings into (blocking, allowed); marks used allow entries.
fn apply_allowlist<'f>(
    findings: &'f [Finding],
    allows: &[Allow],
) -> (Vec<&'f Finding>, Vec<(&'f Finding, String)>) {
    let mut blocking = Vec::new();
    let mut allowed = Vec::new();
    for f in findings {
        let hit = allows.iter().find(|a| {
            a.rule == f.rule && f.file.ends_with(&a.path) && f.msg.contains(&a.contains)
        });
        match hit {
            Some(a) => {
                a.used.set(true);
                allowed.push((f, a.justification.clone()));
            }
            None => blocking.push(f),
        }
    }
    (blocking, allowed)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn print_json(blocking: &[&Finding], unused: &[&Allow]) {
    let mut items: Vec<String> = blocking
        .iter()
        .map(|f| {
            format!(
                r#"{{"rule":"{}","file":"{}","line":{},"msg":"{}"}}"#,
                f.rule,
                json_escape(&f.file),
                f.line,
                json_escape(&f.msg)
            )
        })
        .collect();
    items.extend(unused.iter().map(|a| {
        format!(
            r#"{{"rule":"A","file":"allow.list","line":0,"msg":"unused allow entry: {}|{}|{}"}}"#,
            a.rule,
            json_escape(&a.path),
            json_escape(&a.contains)
        )
    }));
    println!("[{}]", items.join(","));
}

/// Check the checker: `fixtures/good` must be clean, every
/// `// expect: X` annotation in `fixtures/bad` must fire, and nothing
/// unannotated may fire.
fn selftest(fixtures: &Path) -> Result<()> {
    let good = lint_tree(&fixtures.join("good"))?;
    if !good.is_empty() {
        for f in &good {
            eprintln!("  [{}] good/{}:{} {}", f.rule, f.file, f.line, f.msg);
        }
        bail!("selftest: {} finding(s) in fixtures/good", good.len());
    }

    let bad_root = fixtures.join("bad");
    let mut files_checked = 0usize;
    let mut rules_covered: BTreeSet<char> = BTreeSet::new();
    for path in rust_files(&bad_root)? {
        let rel = path
            .strip_prefix(&bad_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)?;
        let expected: BTreeSet<char> = src
            .lines()
            .filter_map(|l| l.trim().strip_prefix("// expect: "))
            .filter_map(|r| r.trim().chars().next())
            .collect();
        if expected.is_empty() {
            bail!("selftest: bad/{rel} has no `// expect: X` annotation");
        }
        let mut findings = Vec::new();
        lint_file(&rel, &src, &mut findings);
        let actual: BTreeSet<char> = findings.iter().map(|f| f.rule).collect();
        if actual != expected {
            for f in &findings {
                eprintln!("  [{}] bad/{}:{} {}", f.rule, f.file, f.line, f.msg);
            }
            bail!(
                "selftest: bad/{rel} expected rules {expected:?}, got {actual:?}"
            );
        }
        files_checked += 1;
        rules_covered.extend(expected);
    }
    for rule in ['U', 'S', 'C', 'H', 'D'] {
        if !rules_covered.contains(&rule) {
            bail!("selftest: no failing fixture covers rule {rule}");
        }
    }
    println!(
        "selftest ok: fixtures/good clean, {files_checked} failing fixtures \
         cover rules {rules_covered:?}"
    );
    Ok(())
}

fn run() -> Result<i32> {
    let mut json = false;
    let mut do_selftest = false;
    let mut src_override: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--selftest" => do_selftest = true,
            "--src" => {
                src_override = Some(PathBuf::from(
                    args.next().context("--src needs a directory")?,
                ));
            }
            other => bail!("unknown argument {other:?} (try --json, --selftest, --src DIR)"),
        }
    }

    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if do_selftest {
        selftest(&manifest.join("fixtures"))?;
        return Ok(0);
    }

    let src_root = src_override.unwrap_or_else(|| manifest.join("../src"));
    let findings = lint_tree(&src_root)?;
    let allows = parse_allowlist(&manifest.join("allow.list"))?;
    let (blocking, allowed) = apply_allowlist(&findings, &allows);
    let unused: Vec<&Allow> = allows.iter().filter(|a| !a.used.get()).collect();

    if json {
        print_json(&blocking, &unused);
    } else {
        for (f, why) in &allowed {
            println!("allowed [{}] {}:{} — {}", f.rule, f.file, f.line, why);
        }
        for f in &blocking {
            println!("FAIL [{}] {}:{} {}", f.rule, f.file, f.line, f.msg);
        }
        for a in &unused {
            println!(
                "FAIL [A] allow.list entry never matched: {}|{}|{} \
                 (stale entries must be deleted)",
                a.rule, a.path, a.contains
            );
        }
        println!(
            "grcim-lint: {} blocking, {} allowed, {} stale allow entries",
            blocking.len(),
            allowed.len(),
            unused.len()
        );
    }
    Ok(if blocking.is_empty() && unused.is_empty() { 0 } else { 1 })
}

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("grcim-lint: error: {e:#}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixtures() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures")
    }

    #[test]
    fn fixtures_selftest_passes() {
        selftest(&fixtures()).expect("selftest");
    }

    #[test]
    fn repo_tree_is_clean_under_the_allowlist() {
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_tree(&manifest.join("../src")).expect("lint runs");
        let allows = parse_allowlist(&manifest.join("allow.list")).expect("allowlist");
        let (blocking, _) = apply_allowlist(&findings, &allows);
        assert!(
            blocking.is_empty(),
            "blocking findings: {:?}",
            blocking.iter().map(|f| format!("[{}] {}:{}", f.rule, f.file, f.line)).collect::<Vec<_>>()
        );
        let unused: Vec<_> = allows.iter().filter(|a| !a.used.get()).collect();
        assert!(
            unused.is_empty(),
            "stale allow entries: {:?}",
            unused.iter().map(|a| format!("{}|{}", a.rule, a.path)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn allowlist_rejects_missing_justification() {
        let dir = std::env::temp_dir().join("grcim-lint-test-allow");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("allow.list");
        std::fs::write(&p, "U|foo.rs|bar|   \n").unwrap();
        assert!(parse_allowlist(&p).is_err());
        std::fs::write(&p, "U|foo.rs|bar\n").unwrap();
        assert!(parse_allowlist(&p).is_err(), "three fields must be rejected");
        std::fs::write(&p, "# comment\n\nU|foo.rs|bar|because\n").unwrap();
        let ok = parse_allowlist(&p).unwrap();
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].justification, "because");
    }

    #[test]
    fn test_gating_detects_cfg_variants() {
        let src = r#"
            #[cfg(test)]
            mod tests { fn f() { let _ = Some(1).unwrap(); } }
            #[cfg(all(test, not(loom)))]
            mod tests2 { fn f() { let _ = Some(1).unwrap(); } }
        "#;
        let mut findings = Vec::new();
        lint_file("server/x.rs", src, &mut findings);
        assert!(findings.is_empty(), "{findings:?}");
    }
}
