//! Loom model-checking of the crate's concurrency protocols.
//!
//! This entire test binary is compiled only under `RUSTFLAGS="--cfg
//! loom"` (CI's `loom` lane); a plain `cargo test` builds an empty
//! harness and skips it. Under `--cfg loom` the library itself is
//! compiled against loom's `Mutex`/`Condvar`/`Arc`/atomics via
//! [`grcim::util::sync`], so the models below exercise the *real*
//! production code — single-flight cache, bounded admission queue,
//! worker pool — across every interleaving loom's bounded exploration
//! reaches, not just the schedules the unit tests happen to hit.
//!
//! Each model keeps to ≤ 3 threads (loom's hard cap is 4 including the
//! model's root thread) and bounds preemptions at 2, which is the
//! published sweet spot: almost all real concurrency bugs manifest
//! within two forced preemptions, while unbounded exploration explodes
//! combinatorially.

#![cfg(loom)]

use grcim::coordinator::pool::run_jobs;
use grcim::server::cache::{Outcome, ShardedCache};
use grcim::util::sync::{lock_recover, Arc, BoundedQueue, Mutex};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Run `f` under loom with the standard preemption bound.
fn model(f: impl Fn() + Send + Sync + 'static) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(2);
    b.check(f);
}

/// Two concurrent requests for the same key perform exactly one
/// computation, and both observe the leader's value — the single-flight
/// invariant the serve layer's byte-identical-hit guarantee rests on.
#[test]
fn single_flight_computes_once() {
    model(|| {
        let c: Arc<ShardedCache<u64>> = Arc::new(ShardedCache::new(16));
        let c2 = Arc::clone(&c);
        let t = loom::thread::spawn(move || {
            let (v, _) = c2.get_or_compute("k", || Ok(40)).unwrap();
            *v
        });
        let (v_main, _) = c.get_or_compute("k", || Ok(40)).unwrap();
        let v_spawned = t.join().unwrap();

        assert_eq!(v_main, 40);
        assert_eq!(v_spawned, 40);
        let s = c.stats();
        assert_eq!(s.computes, 1, "single-flight violated: {s:?}");
        assert_eq!(s.entries, 1);
        // every lookup is accounted for exactly once
        assert_eq!(s.hits + s.coalesced + s.computes, 2);
    });
}

/// A leader whose compute *panics* (not `Err`s) must wake any follower
/// with a clean error — never leave it blocked on the flight condvar —
/// and must not wedge the key: a later request computes fresh.
#[test]
fn single_flight_leader_panic_wakes_followers() {
    model(|| {
        let c: Arc<ShardedCache<u64>> = Arc::new(ShardedCache::new(16));

        let c_panicker = Arc::clone(&c);
        let panicker = loom::thread::spawn(move || {
            // if this thread leads, its compute panics and FlightGuard
            // must clean up; if it coalesces, it sees the other
            // thread's result (Ok or the panic error) instead
            let res = catch_unwind(AssertUnwindSafe(|| {
                c_panicker.get_or_compute("k", || -> anyhow::Result<u64> {
                    panic!("compute exploded");
                })
            }));
            if let Ok(inner) = res {
                match inner {
                    Ok((v, o)) => {
                        // coalesced onto (or hit) the healthy compute
                        assert_eq!(*v, 5);
                        assert!(o.is_cached(), "got {o:?}");
                    }
                    Err(e) => {
                        assert!(format!("{e:#}").contains("panicked"), "{e:#}")
                    }
                }
            }
        });

        // the healthy caller either leads (Ok(5)), coalesces onto the
        // panicking flight (clean error naming the panic), or arrives
        // after the guard's cleanup and recomputes — hanging is the
        // only failure, and loom's deadlock detection would report it
        match c.get_or_compute("k", || Ok(5)) {
            Ok((v, _)) => assert_eq!(*v, 5),
            Err(e) => assert!(format!("{e:#}").contains("panicked"), "{e:#}"),
        }
        panicker.join().unwrap();

        // the key is not poisoned: a later request is served normally
        let (v, o) = c.get_or_compute("k", || Ok(7)).unwrap();
        assert!(*v == 5 || *v == 7, "got {v}");
        assert!(matches!(o, Outcome::Computed | Outcome::Hit));
    });
}

/// The compute-queue protocol ([`BoundedQueue`] behind the reactor's
/// `ComputeQueue` alias): admission up to `cap`, busy-rejection at
/// `cap`, FIFO drain, a popper blocked on an empty queue woken by
/// `close`, and post-close pushes rejected.
#[test]
fn bounded_queue_admission_and_close_drain() {
    model(|| {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(2));
        assert!(q.try_push(1));
        assert!(q.try_push(2));
        assert!(!q.try_push(3), "queue admitted past its cap");

        let q2 = Arc::clone(&q);
        let popper = loom::thread::spawn(move || {
            // FIFO across the close: both admitted items drain in
            // order; the third pop blocks until close() and must then
            // observe None, never hang (loom would flag the deadlock)
            assert_eq!(q2.pop(), Some(1));
            assert_eq!(q2.pop(), Some(2));
            assert_eq!(q2.pop(), None);
        });

        q.close();
        assert!(!q.try_push(4), "closed queue admitted a job");
        popper.join().unwrap();
    });
}

/// A panicking job inside [`run_jobs`] surfaces as a clean `Err` naming
/// the panic in every interleaving — no poisoned queue cascade, no
/// stuck worker (a worker failing to exit would trip loom's deadlock
/// detection at join).
#[test]
fn pool_panicking_job_is_clean_error() {
    model(|| {
        let res: anyhow::Result<Vec<u32>> =
            run_jobs(vec![0u32, 1, 2], 2, || {
                Ok(|j: u32| {
                    if j == 1 {
                        panic!("job exploded");
                    }
                    Ok(j)
                })
            });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("job exploded"), "{err}");
    });
}

/// The checkpoint append protocol, reduced to its locking skeleton: a
/// writer holds the log lock across the *whole* line (payload plus
/// newline), so a concurrent snapshot reader can observe any prefix of
/// whole lines but never a torn one. This is exactly the invariant
/// `explore/checkpoint.rs` relies on for crash-tolerant resume (its
/// reader drops at most one trailing partial line — which only a
/// process crash, not a concurrent writer, may produce).
#[test]
fn checkpoint_appends_are_whole_lines() {
    model(|| {
        let log: Arc<Mutex<String>> = Arc::new(Mutex::new(String::new()));

        let spawn_writer = |tag: &'static str| {
            let log = Arc::clone(&log);
            loom::thread::spawn(move || {
                // one lock acquisition spans payload + newline; were
                // these separate acquisitions, loom would find the
                // interleaving where the reader sees a torn line
                let mut f = lock_recover(&log);
                f.push_str(tag);
                f.push('\n');
            })
        };
        let w1 = spawn_writer("alpha");
        let w2 = spawn_writer("beta");

        // concurrent snapshot: only whole lines, in any order
        {
            let snap = lock_recover(&log).clone();
            assert!(snap.is_empty() || snap.ends_with('\n'), "torn tail: {snap:?}");
            for line in snap.lines() {
                assert!(line == "alpha" || line == "beta", "torn line: {line:?}");
            }
        }

        w1.join().unwrap();
        w2.join().unwrap();
        let fin = lock_recover(&log).clone();
        let mut lines: Vec<&str> = fin.lines().collect();
        lines.sort_unstable();
        assert_eq!(lines, ["alpha", "beta"]);
    });
}
