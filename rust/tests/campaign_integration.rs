//! Coordinator integration: campaigns over the PJRT backend, the
//! auto-fallback path, CLI-level sweep configs, and the e2e NN pipeline.

use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::formats::FpFormat;
use grcim::mac::FormatPair;
use grcim::nn::{accuracy, cim_accuracy, make_blobs, CimInference, Mlp};
use grcim::rng::Pcg64;
use grcim::runtime::{ArtifactRegistry, EngineKind};
use grcim::spec::{required_enob, Arch, SpecConfig};

fn have_artifacts() -> bool {
    ArtifactRegistry::load(&ArtifactRegistry::default_dir()).is_ok()
}

fn demo_specs() -> Vec<ExperimentSpec> {
    vec![
        ExperimentSpec {
            id: "a".into(),
            fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: 4096,
            sampler: Default::default(),
        },
        ExperimentSpec {
            id: "b".into(),
            fmts: FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1()),
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 64,
            samples: 2048,
            sampler: Default::default(),
        },
    ]
}

#[test]
fn pjrt_campaign_end_to_end() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = CampaignConfig {
        engine: EngineKind::Pjrt,
        workers: 2,
        seed: 7,
        ..Default::default()
    };
    let aggs = run_campaign(&demo_specs(), &cfg).unwrap();
    assert_eq!(aggs.len(), 2);
    assert_eq!(aggs[0].samples(), 4096);
    assert_eq!(aggs[1].samples(), 2048);
    // spec solver produces sane ENOBs from the PJRT-backed aggregates
    let cfg2 = SpecConfig::default();
    for agg in &aggs {
        let conv = required_enob(agg, Arch::Conventional, cfg2).enob;
        let gr = required_enob(agg, Arch::GrUnit, cfg2).enob;
        assert!(conv > gr, "conv {conv} gr {gr}");
        assert!((2.0..20.0).contains(&conv));
    }
}

#[test]
fn pjrt_and_rust_campaigns_agree_on_identical_streams() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let specs = demo_specs();
    let mk = |engine| CampaignConfig {
        engine,
        workers: 3,
        seed: 99,
        ..Default::default()
    };
    let p = run_campaign(&specs, &mk(EngineKind::Pjrt)).unwrap();
    let r = run_campaign(&specs, &mk(EngineKind::Rust)).unwrap();
    for (a, b) in p.iter().zip(&r) {
        assert_eq!(a.samples(), b.samples());
        let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1e-30);
        assert!(rel(a.nf.mean(), b.nf.mean()) < 1e-4);
        assert!(rel(a.g_unit.mean_sq(), b.g_unit.mean_sq()) < 1e-4);
        assert!(rel(a.mean_n_eff(), b.mean_n_eff()) < 1e-4);
    }
}

#[test]
fn auto_engine_falls_back_when_artifacts_missing() {
    let cfg = CampaignConfig {
        engine: EngineKind::Auto,
        artifacts_dir: std::path::PathBuf::from("/nonexistent/grcim-artifacts"),
        workers: 1,
        seed: 1,
    };
    let specs = vec![ExperimentSpec {
        id: "fallback".into(),
        fmts: FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1()),
        dist_x: Distribution::Uniform,
        dist_w: Distribution::Uniform,
        nr: 8,
        samples: 2048,
        sampler: Default::default(),
    }];
    let aggs = run_campaign(&specs, &cfg).unwrap();
    assert_eq!(aggs[0].samples(), 2048);
}

#[test]
fn pjrt_engine_rejects_missing_depth_in_campaign() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = CampaignConfig {
        engine: EngineKind::Pjrt,
        workers: 1,
        seed: 1,
        ..Default::default()
    };
    let specs = vec![ExperimentSpec {
        id: "bad-depth".into(),
        fmts: FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1()),
        dist_x: Distribution::Uniform,
        dist_w: Distribution::Uniform,
        nr: 24, // no artifact lowered for this depth
        samples: 2048,
        sampler: Default::default(),
    }];
    assert!(run_campaign(&specs, &cfg).is_err());
}

#[test]
fn sweep_config_round_trip() {
    // the TOML config the `grcim sweep` command consumes
    let text = r#"
seed = 5
samples = 2048

[engine]
kind = "rust"

[[experiment]]
name = "fp63-uniform"
n_e = 3
n_m = 2
nr = 32
distribution = "uniform"

[[experiment]]
name = "fp42-llm"
n_e = 4
n_m = 2
nr = 32
distribution = "gauss_outliers"
"#;
    let cfg = grcim::config::Config::parse(text).unwrap();
    assert_eq!(cfg.sections_named("experiment").len(), 2);
    assert_eq!(
        cfg.section("engine").unwrap()["kind"].as_str(),
        Some("rust")
    );
}

#[test]
fn nn_e2e_through_pjrt_tiles() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let engine = grcim::runtime::build_engine(
        EngineKind::Pjrt,
        &ArtifactRegistry::default_dir(),
    )
    .unwrap();
    let (xs, ys) = make_blobs(768, 32, 4, 0.3, 3);
    let mut mlp = Mlp::new(&[32, 32, 4], 1);
    let mut rng = Pcg64::seeded(2);
    for _ in 0..25 {
        mlp.train_epoch(&xs[..512], &ys[..512], 0.05, &mut rng);
    }
    let float_acc = accuracy(&mlp, &xs[512..], &ys[512..]);
    assert!(float_acc > 0.9, "training failed: {float_acc}");
    let cim = CimInference {
        fmts: FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3()),
        arch: Arch::GrUnit,
        enob: 9.0,
        nr: 32,
        nc: 32,
    };
    let acc = cim_accuracy(&mlp, engine.as_ref(), &cim, &xs[512..], &ys[512..])
        .unwrap();
    assert!(
        acc >= float_acc - 0.05,
        "pjrt cim accuracy {acc} vs float {float_acc}"
    );
}
