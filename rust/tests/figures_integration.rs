//! Figure harness integration: every generator runs end-to-end in quick
//! mode, persists its CSVs, and its paper-shape checks hold. (The
//! heavyweight figures run through the same code in `cargo bench` and via
//! the CLI; this keeps `cargo test` within a couple of minutes.)

use grcim::figures::{self, FigureCtx};
use grcim::runtime::EngineKind;

fn ctx(tag: &str) -> FigureCtx {
    let mut ctx = FigureCtx::default().quick();
    ctx.campaign.engine = EngineKind::Rust; // deterministic, artifact-free
    ctx.out_dir = std::env::temp_dir().join(format!("grcim_figtest_{tag}"));
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
    ctx
}

fn run_and_check(id: &str) {
    let ctx = ctx(id);
    let fr = figures::run(id, &ctx).unwrap();
    assert_eq!(fr.name, id);
    assert!(!fr.tables.is_empty(), "{id}: no tables");
    assert!(!fr.checks.is_empty(), "{id}: no checks");
    assert!(fr.all_hold(), "{id}: checks failed: {:#?}", fr.checks);
    let text = fr.emit(&ctx.out_dir).unwrap();
    assert!(text.contains(id));
    // at least one CSV landed
    let n_csv = std::fs::read_dir(&ctx.out_dir)
        .unwrap()
        .filter(|e| {
            e.as_ref()
                .unwrap()
                .path()
                .extension()
                .map(|x| x == "csv")
                .unwrap_or(false)
        })
        .count();
    assert!(n_csv >= 1, "{id}: no CSVs written");
}

#[test]
fn fig4_end_to_end() {
    run_and_check("fig4");
}

#[test]
fn table1_end_to_end() {
    run_and_check("table1");
}

#[test]
fn fig8_end_to_end() {
    run_and_check("fig8");
}

#[test]
fn fig9_end_to_end() {
    run_and_check("fig9");
}

#[test]
fn fig10_end_to_end() {
    run_and_check("fig10");
}

#[test]
fn fig11_end_to_end() {
    run_and_check("fig11");
}

#[test]
fn fig12_end_to_end() {
    run_and_check("fig12");
}

#[test]
fn ablations_end_to_end() {
    run_and_check("ablations");
}

#[test]
fn fig10_pjrt_engine_if_available() {
    // same figure through the PJRT backend must also hold
    if grcim::runtime::ArtifactRegistry::load(
        &grcim::runtime::ArtifactRegistry::default_dir(),
    )
    .is_err()
    {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut ctx = ctx("fig10_pjrt");
    ctx.campaign.engine = EngineKind::Pjrt;
    let fr = figures::run("fig10", &ctx).unwrap();
    assert!(fr.all_hold(), "{:#?}", fr.checks);
}
