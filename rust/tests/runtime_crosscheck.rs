//! Integration: the PJRT engine (executing the AOT-lowered Pallas kernel)
//! must agree with the pure-Rust f64 oracle on every output, across
//! formats, distributions, and array depths.
//!
//! Only meaningful for `--features pjrt` builds (compiled out otherwise);
//! at runtime it additionally requires AOT artifacts (regenerated with
//! `python/compile/aot.py`) and skips with a notice when they are absent.
#![cfg(feature = "pjrt")]

use grcim::coordinator::{run_experiment, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::formats::FpFormat;
use grcim::mac::FormatPair;
use grcim::rng::Pcg64;
use grcim::runtime::{ArtifactRegistry, Engine, PjrtEngine, RustEngine};

fn registry() -> Option<ArtifactRegistry> {
    let dir = ArtifactRegistry::default_dir();
    match ArtifactRegistry::load(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!(
                "SKIP (no artifacts: {e}) — regenerate with python/compile/aot.py"
            );
            None
        }
    }
}

fn gen_inputs(
    n: usize,
    dist_x: &Distribution,
    dist_w: &Distribution,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::seeded(seed);
    let mut x = vec![0.0f32; n];
    let mut w = vec![0.0f32; n];
    dist_x.fill_f32(&mut rng, &mut x);
    dist_w.fill_f32(&mut rng, &mut w);
    (x, w)
}

struct FieldTol {
    name: &'static str,
    /// absolute tolerance on per-sample values (f32 artifact vs f64 oracle)
    abs: f64,
}

const FIELDS: &[FieldTol] = &[
    FieldTol { name: "z_ideal", abs: 3e-6 },
    FieldTol { name: "z_q", abs: 3e-6 },
    FieldTol { name: "v_conv", abs: 3e-6 },
    FieldTol { name: "g_conv", abs: 1e-6 },
    FieldTol { name: "v_gr", abs: 5e-6 },
    FieldTol { name: "s_sum", abs: 1e-4 },
    FieldTol { name: "s2_sum", abs: 1e-4 },
    FieldTol { name: "sx_sum", abs: 1e-4 },
    FieldTol { name: "g_w", abs: 1e-6 },
    FieldTol { name: "nf", abs: 1e-9 },
    FieldTol { name: "wq2_mean", abs: 3e-6 },
];

fn compare(
    pjrt: &grcim::stats::ColumnBatch,
    rust: &grcim::stats::ColumnBatch,
    ctx: &str,
) -> usize {
    let fields_p: [&Vec<f64>; 11] = [
        &pjrt.z_ideal, &pjrt.z_q, &pjrt.v_conv, &pjrt.g_conv, &pjrt.v_gr,
        &pjrt.s_sum, &pjrt.s2_sum, &pjrt.sx_sum, &pjrt.g_w, &pjrt.nf,
        &pjrt.wq2_mean,
    ];
    let fields_r: [&Vec<f64>; 11] = [
        &rust.z_ideal, &rust.z_q, &rust.v_conv, &rust.g_conv, &rust.v_gr,
        &rust.s_sum, &rust.s2_sum, &rust.sx_sum, &rust.g_w, &rust.nf,
        &rust.wq2_mean,
    ];
    let mut mismatches = 0usize;
    for ((tol, p), r) in FIELDS.iter().zip(fields_p).zip(fields_r) {
        assert_eq!(p.len(), r.len(), "{ctx}: length {}", tol.name);
        for i in 0..p.len() {
            let scale = r[i].abs().max(1.0);
            if (p[i] - r[i]).abs() > tol.abs * scale {
                mismatches += 1;
                if mismatches < 5 {
                    eprintln!(
                        "{ctx}: {}[{i}] pjrt={} rust={} (diff {:.3e})",
                        tol.name,
                        p[i],
                        r[i],
                        (p[i] - r[i]).abs()
                    );
                }
            }
        }
    }
    mismatches
}

#[test]
fn pjrt_matches_rust_oracle_across_formats_and_distributions() {
    let Some(reg) = registry() else { return };
    let pjrt = PjrtEngine::from_registry(&reg).expect("compile artifacts");
    let rust = RustEngine;
    let nr = 32;
    let batch = pjrt.preferred_batch(nr);

    let cases: Vec<(FormatPair, Distribution, Distribution, u64)> = vec![
        (
            FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            Distribution::Uniform,
            Distribution::max_entropy(FpFormat::fp4_e2m1()),
            1,
        ),
        (
            FormatPair::new(FpFormat::fp(2, 3), FpFormat::fp(2, 3)),
            Distribution::clipped_gauss4(),
            Distribution::clipped_gauss4(),
            2,
        ),
        (
            FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1()),
            Distribution::gauss_outliers(),
            Distribution::max_entropy(FpFormat::fp4_e2m1()),
            3,
        ),
        (
            // fractional format (design-space grid point)
            FormatPair::new(
                FpFormat { e_max: 5.5, n_m: 2.25 },
                FpFormat::fp4_e2m1(),
            ),
            Distribution::Uniform,
            Distribution::Uniform,
            4,
        ),
        (
            // INT degenerate case
            FormatPair::new(FpFormat::int(4), FpFormat::int(4)),
            Distribution::Uniform,
            Distribution::Uniform,
            5,
        ),
    ];

    for (fmts, dx, dw, seed) in cases {
        let (x, w) = gen_inputs(batch * nr, &dx, &dw, seed);
        let bp = pjrt.simulate(&x, &w, nr, fmts).expect("pjrt run");
        let br = rust.simulate(&x, &w, nr, fmts).expect("rust run");
        let ctx = format!("fmts={fmts:?} dist={}", dx.name());
        let bad = compare(&bp, &br, &ctx);
        let frac = bad as f64 / (11 * batch) as f64;
        // f32 vs f64 rounding at quantizer decision boundaries can flip a
        // handful of samples; demand bit-level agreement for 99.9%.
        assert!(
            frac < 1e-3,
            "{ctx}: {bad} mismatched values ({frac:.2e} of outputs)"
        );
    }
}

#[test]
fn pjrt_supports_all_artifact_depths() {
    let Some(reg) = registry() else { return };
    let pjrt = PjrtEngine::from_registry(&reg).expect("compile artifacts");
    let rust = RustEngine;
    let fmts = FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3());
    for nr in pjrt.depths() {
        let batch = pjrt.preferred_batch(nr);
        let (x, w) = gen_inputs(
            batch * nr,
            &Distribution::clipped_gauss4(),
            &Distribution::clipped_gauss4(),
            nr as u64,
        );
        let bp = pjrt.simulate(&x, &w, nr, fmts).expect("pjrt");
        let br = rust.simulate(&x, &w, nr, fmts).expect("rust");
        let bad = compare(&bp, &br, &format!("nr={nr}"));
        assert!(bad < 11 * batch / 1000 + 5, "nr={nr}: {bad} mismatches");
    }
}

#[test]
fn pjrt_multi_chunk_execution() {
    let Some(reg) = registry() else { return };
    let pjrt = PjrtEngine::from_registry(&reg).expect("compile artifacts");
    let nr = 16;
    let batch = pjrt.preferred_batch(nr);
    let fmts = FormatPair::new(FpFormat::fp4_e2m1(), FpFormat::fp4_e2m1());
    let (x, w) =
        gen_inputs(3 * batch * nr, &Distribution::Uniform, &Distribution::Uniform, 9);
    let b = pjrt.simulate(&x, &w, nr, fmts).expect("multi-chunk");
    assert_eq!(b.len(), 3 * batch);
    // ragged input rejected
    assert!(pjrt.simulate(&x[..nr * 7], &w[..nr * 7], nr, fmts).is_err());
    // unknown depth rejected
    assert!(pjrt.simulate(&x, &w, 24, fmts).is_err());
}

#[test]
fn experiment_aggregates_agree_between_engines() {
    // campaign-level agreement: aggregate moments from both engines match
    // to Monte-Carlo-irrelevant precision on identical streams
    let Some(reg) = registry() else { return };
    let pjrt = PjrtEngine::from_registry(&reg).expect("compile artifacts");
    let rust = RustEngine;
    let spec = ExperimentSpec {
        id: "xcheck".into(),
        fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
        dist_x: Distribution::gauss_outliers(),
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr: 32,
        samples: 4096,
        sampler: Default::default(),
    };
    let ap = run_experiment(&pjrt, &spec, 42).unwrap();
    let ar = run_experiment(&rust, &spec, 42).unwrap();
    assert_eq!(ap.samples(), ar.samples());
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-30);
    assert!(rel(ap.nf.mean(), ar.nf.mean()) < 1e-4);
    assert!(rel(ap.g_conv.mean_sq(), ar.g_conv.mean_sq()) < 1e-4);
    assert!(rel(ap.g_unit.mean_sq(), ar.g_unit.mean_sq()) < 1e-4);
    assert!(rel(ap.mean_n_eff(), ar.mean_n_eff()) < 1e-4);
    // and the spec solver lands on the same ENOB from either engine
    let cfg = grcim::spec::SpecConfig::default();
    for arch in [
        grcim::spec::Arch::Conventional,
        grcim::spec::Arch::GrUnit,
        grcim::spec::Arch::GrRow,
    ] {
        let ep = grcim::spec::required_enob(&ap, arch, cfg).enob;
        let er = grcim::spec::required_enob(&ar, arch, cfg).enob;
        assert!((ep - er).abs() < 1e-3, "{arch:?}: {ep} vs {er}");
    }
}
