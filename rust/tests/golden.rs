//! Golden-value regression suite: small deterministic campaigns (fixed
//! seeds, `RustEngine`) and closed-form analog figures, pinned against
//! committed JSON snapshots under `rust/tests/golden/` and compared via
//! the in-repo `config::json` parser.
//!
//! * Regenerate snapshots with `GOLDEN_UPDATE=1 cargo test -q --test golden`.
//! * Each file carries its own `_tol` (relative). Pure-arithmetic paths
//!   (Table 1, Fig. 8 staircases) pin to ~1e-10; Monte-Carlo statistics
//!   pin to 1e-6 — tight enough that perturbing any spec constant (paper
//!   capacitor values, the 6 dB ADC margin, format/distribution
//!   parameters, seeding) fails the suite, loose enough to absorb 1-ulp
//!   libm differences across platforms.
//!
//! The committed snapshots were produced by the independent Python twin
//! `tools/gen_goldens.py`, which re-implements the seeded pipeline
//! (SplitMix64/PCG64, FP quantizer, column MAC, ADC spec solver, GR-MAC
//! cell design) in exact IEEE-754 f64 — so these tests also cross-check
//! the Rust implementation against a second implementation, not just
//! against its own history.

use grcim::config::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// Compare measured values against a golden map. Returns every violation
/// (missing/extra keys, out-of-tolerance values) as messages.
fn compare(
    golden: &BTreeMap<String, f64>,
    measured: &[(String, f64)],
    tol: f64,
) -> Result<(), Vec<String>> {
    let mut errs = Vec::new();
    let measured_map: BTreeMap<&str, f64> =
        measured.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    for (k, &g) in golden {
        match measured_map.get(k.as_str()) {
            None => errs.push(format!("golden key '{k}' not measured")),
            Some(&m) => {
                let scale = g.abs().max(m.abs()).max(1e-12);
                let rel = (m - g).abs() / scale;
                if !(rel <= tol) {
                    errs.push(format!(
                        "{k}: measured {m} vs golden {g} (rel {rel:.3e} > {tol:.1e})"
                    ));
                }
            }
        }
    }
    for (k, _) in measured {
        if !golden.contains_key(k) {
            errs.push(format!("measured key '{k}' missing from golden file"));
        }
    }
    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs)
    }
}

/// One golden snapshot under construction.
struct Golden {
    name: &'static str,
    tol: f64,
    values: Vec<(String, f64)>,
}

impl Golden {
    fn new(name: &'static str, tol: f64) -> Self {
        Golden { name, tol, values: Vec::new() }
    }

    fn push(&mut self, key: impl Into<String>, v: f64) {
        assert!(v.is_finite(), "golden values must be finite");
        self.values.push((key.into(), v));
    }

    fn write(&self) {
        let path = golden_dir().join(format!("{}.json", self.name));
        let mut values = BTreeMap::new();
        for (k, v) in &self.values {
            values.insert(k.clone(), Json::Num(*v));
        }
        let mut root = BTreeMap::new();
        root.insert("_tol".to_string(), Json::Num(self.tol));
        root.insert("values".to_string(), Json::Obj(values));
        std::fs::create_dir_all(golden_dir()).expect("create golden dir");
        std::fs::write(&path, Json::Obj(root).to_string())
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        eprintln!("GOLDEN_UPDATE: wrote {}", path.display());
    }

    /// Compare against the committed snapshot (or rewrite it under
    /// GOLDEN_UPDATE=1).
    fn check(self) {
        if std::env::var("GOLDEN_UPDATE").ok().as_deref() == Some("1") {
            self.write();
            return;
        }
        let path = golden_dir().join(format!("{}.json", self.name));
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!(
                "missing golden snapshot {}: {e}\n\
                 regenerate with: GOLDEN_UPDATE=1 cargo test -q --test golden",
                path.display()
            )
        });
        let j = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
        let tol = j
            .get("_tol")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("{}: missing _tol", path.display()));
        let Some(Json::Obj(map)) = j.get("values") else {
            panic!("{}: missing 'values' object", path.display());
        };
        let golden: BTreeMap<String, f64> = map
            .iter()
            .map(|(k, v)| {
                (
                    k.clone(),
                    v.as_f64().unwrap_or_else(|| {
                        panic!("{}: non-numeric value '{k}'", path.display())
                    }),
                )
            })
            .collect();
        if let Err(errs) = compare(&golden, &self.values, tol) {
            panic!(
                "golden snapshot '{}' drifted ({} violations):\n  {}\n\
                 (if the change is intentional, regenerate with \
                 GOLDEN_UPDATE=1 cargo test -q --test golden)",
                self.name,
                errs.len(),
                errs.join("\n  ")
            );
        }
    }
}

// ---------------------------------------------------------------------
// Table 1 — GR-MAC capacitor design values (closed form, no RNG).
// ---------------------------------------------------------------------

#[test]
fn golden_table1_capacitors() {
    use grcim::analog::GrMacCell;
    use grcim::figures::table1::{PAPER_C_E, PAPER_C_M};

    let mut g = Golden::new("table1", 1e-10);
    let schematic = GrMacCell::fp6_e2m3_schematic();
    let comp05 = GrMacCell::design(4, 4, 1.0, 0.5);
    let comp10 = GrMacCell::design(4, 4, 1.0, 1.0);

    for (label, cell) in
        [("schematic", &schematic), ("comp05", &comp05), ("comp10", &comp10)]
    {
        for (i, &c) in cell.c_m.iter().enumerate() {
            g.push(format!("{label}_c_m{i}"), c);
        }
        for (i, &c) in cell.c_e.iter().enumerate() {
            g.push(format!("{label}_c_e{}", i + 1), c);
        }
        for level in 1..=cell.levels() {
            g.push(
                format!("{label}_coupling_t{level}"),
                cell.coupling_total(level),
            );
            g.push(
                format!("{label}_q_w15_l{level}"),
                cell.transfer_closed_form(15, level, 1.0),
            );
        }
    }
    // the paper constants themselves participate so a perturbed spec
    // constant in figures::table1 fails the suite
    for (i, &c) in PAPER_C_M.iter().enumerate() {
        g.push(format!("paper_c_m{i}"), c);
    }
    for (i, &c) in PAPER_C_E.iter().enumerate() {
        g.push(format!("paper_c_e{}", i + 1), c);
    }
    g.check();
}

// ---------------------------------------------------------------------
// Fig. 8 — cell linearity staircases and octave gains (closed form).
// ---------------------------------------------------------------------

#[test]
fn golden_fig8_staircases() {
    use grcim::analog::{mismatch::w_sweep, GrMacCell};

    let mut g = Golden::new("fig8", 1e-10);
    let cell = GrMacCell::fp6_e2m3_schematic();
    for level in 1..=cell.levels() {
        let vals = w_sweep(&cell, level, 1.0);
        for w in [1usize, 7, 15] {
            g.push(format!("q_l{level}_w{w}"), vals[w]);
        }
        g.push(format!("lsb_l{level}"), cell.lsb(level, 1.0));
        if level >= 2 {
            let top = cell.m_codes() - 1;
            let ratio = cell.transfer_closed_form(top, level, 1.0)
                / cell.transfer_closed_form(top, level - 1, 1.0);
            g.push(format!("octave_ratio_l{level}"), ratio);
        }
    }
    g.check();
}

// ---------------------------------------------------------------------
// Fig. 9 — element-level SQNR series (seeded Monte Carlo).
// ---------------------------------------------------------------------

const FIG9_SAMPLES: usize = 16_384;
const FIG9_SEED: u64 = 0xF19D;

#[test]
fn golden_fig9_sqnr_series() {
    let mut g = Golden::new("fig9", 1e-6);
    let series = grcim::figures::fig9::sqnr_series(FIG9_SAMPLES, FIG9_SEED);
    let names = ["uniform", "max_entropy", "gauss_outliers", "gauss_core"];
    for (i, row) in series.iter().enumerate() {
        for (j, name) in names.iter().enumerate() {
            g.push(format!("ne{i}_{name}"), row[j]);
        }
    }
    g.check();
}

// ---------------------------------------------------------------------
// ENOB solutions — seeded RustEngine campaigns through the full stack
// (rng -> distributions -> f32 inputs -> column MAC -> moments -> spec).
// ---------------------------------------------------------------------

const CAMPAIGN_SEED: u64 = 42;
const CAMPAIGN_SAMPLES: usize = 2048;

fn campaign_specs() -> Vec<grcim::coordinator::ExperimentSpec> {
    use grcim::coordinator::ExperimentSpec;
    use grcim::distributions::Distribution;
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    vec![
        // Fig. 10 mid-sweep point: FP(3,2) activations, uniform inputs
        ExperimentSpec {
            id: "ne3-uniform".into(),
            fmts: FormatPair::new(FpFormat::fp(3, 2), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: CAMPAIGN_SAMPLES,
            sampler: Default::default(),
        },
        // the LLM stress point: FP(4,2) + gauss/outliers activations
        ExperimentSpec {
            id: "ne4-llm".into(),
            fmts: FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1()),
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: CAMPAIGN_SAMPLES,
            sampler: Default::default(),
        },
        // INT degenerate case at a different depth
        ExperimentSpec {
            id: "int6".into(),
            fmts: FormatPair::new(FpFormat::int(6), FpFormat::int(4)),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::Uniform,
            nr: 16,
            samples: CAMPAIGN_SAMPLES,
            sampler: Default::default(),
        },
    ]
}

#[test]
fn golden_campaign_enob_solutions() {
    use grcim::coordinator::run_experiment;
    use grcim::runtime::RustEngine;
    use grcim::spec::{delta_enob, required_enob, Arch, SpecConfig};

    let mut g = Golden::new("campaign_enob", 1e-6);
    let engine = RustEngine;
    let cfg = SpecConfig::default();
    for spec in campaign_specs() {
        let agg = run_experiment(&engine, &spec, CAMPAIGN_SEED).unwrap();
        assert_eq!(agg.samples() as usize, CAMPAIGN_SAMPLES);
        let tag = spec.id.clone();
        g.push(
            format!("{tag}_enob_conv"),
            required_enob(&agg, Arch::Conventional, cfg).enob,
        );
        g.push(
            format!("{tag}_enob_unit"),
            required_enob(&agg, Arch::GrUnit, cfg).enob,
        );
        g.push(
            format!("{tag}_enob_row"),
            required_enob(&agg, Arch::GrRow, cfg).enob,
        );
        g.push(format!("{tag}_delta_enob"), delta_enob(&agg, cfg));
        g.push(format!("{tag}_mean_n_eff"), agg.mean_n_eff());
        g.push(format!("{tag}_power_gain"), agg.signal_power_gain());
        g.push(format!("{tag}_sqnr_db"), agg.sqnr_db());
        g.push(format!("{tag}_nf_mean"), agg.nf.mean());
        g.push(format!("{tag}_g_unit_ms"), agg.g_unit.mean_sq());
        g.push(format!("{tag}_g_row_ms"), agg.g_row.mean_sq());
    }
    g.check();
}

// ---------------------------------------------------------------------
// Samples-for-equal-CI — the --target-ci estimator-mode pilot, pinned at
// the acceptance spec point (FP(4,3) near 35 dB under clipped-Gaussian
// activations) and cross-checked against the Python twin's
// samples_for_ci_twin.
// ---------------------------------------------------------------------

const CI_GOLDEN_SEED: u64 = 0xC1;
const CI_GOLDEN_HALF_DB: f64 = 0.25;

#[test]
fn golden_samples_ci() {
    use grcim::coordinator::{samples_for_ci, ExperimentSpec, CI_PILOT_SAMPLES};
    use grcim::distributions::Distribution;
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    use grcim::runtime::RustEngine;

    let spec = ExperimentSpec {
        id: "ci35".into(),
        fmts: FormatPair::new(FpFormat::fp(4, 3), FpFormat::fp4_e2m1()),
        dist_x: Distribution::clipped_gauss4(),
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr: 32,
        samples: CI_PILOT_SAMPLES,
        sampler: Default::default(),
    };
    let ests =
        samples_for_ci(&RustEngine, &spec, CI_GOLDEN_SEED, CI_GOLDEN_HALF_DB)
            .unwrap();
    let mut g = Golden::new("samples_ci", 1e-6);
    for est in &ests {
        let tag = est.sampler.name();
        g.push(format!("{tag}_sqnr_db_mean"), est.sqnr_db_mean);
        g.push(format!("{tag}_sqnr_db_std"), est.sqnr_db_std);
        g.push(
            format!("{tag}_required_samples"),
            est.required_samples as f64,
        );
    }
    g.check();
}

// ---------------------------------------------------------------------
// Workload — empirical-trace fit, SQNR sweep, and trace-driven ENOB
// (rng -> f32 trace -> EmpiricalDist -> inverse-CDF sampling -> campaign).
// ---------------------------------------------------------------------

const WORKLOAD_TRACE_SEED: u64 = 0xE3;
const WORKLOAD_TRACE_N: usize = 4096;
const WORKLOAD_SQNR_SAMPLES: usize = 8192;
const WORKLOAD_SQNR_SEED: u64 = 0x17E;

#[test]
fn golden_workload_empirical() {
    use grcim::coordinator::{run_experiment, ExperimentSpec};
    use grcim::distributions::Distribution;
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    use grcim::rng::Pcg64;
    use grcim::runtime::RustEngine;
    use grcim::spec::{required_enob, Arch, SpecConfig};
    use grcim::workload::{sqnr_sweep, EmpiricalDist, TensorTrace};
    use std::sync::Arc;

    let mut g = Golden::new("workload_empirical", 1e-6);

    // the synthetic-LLM trace (same seeded draws as the Python twin)
    let mut rng = Pcg64::seeded(WORKLOAD_TRACE_SEED);
    let mut raw = vec![0.0f32; WORKLOAD_TRACE_N];
    Distribution::gauss_outliers().fill_f32(&mut rng, &mut raw);
    let trace =
        TensorTrace::from_f32("golden-llm", vec![WORKLOAD_TRACE_N], raw)
            .unwrap();
    let fit = Arc::new(EmpiricalDist::fit(&trace).unwrap());

    g.push("fit_scale", fit.scale());
    g.push("fit_dr_bits", fit.dr_bits());
    g.push("fit_sigma_core", fit.sigma_core());
    g.push("fit_outlier_mass", fit.outlier_mass());
    g.push("fit_mean", fit.mean());
    g.push("fit_std", fit.std());
    for j in [0usize, 128, 256, 384, 512] {
        g.push(
            format!("fit_knot{j}"),
            fit.quantile(j as f64 / 512.0),
        );
    }

    // Fig. 9-style SQNR sweep over the fitted distribution
    let dist = Distribution::Empirical(Arc::clone(&fit));
    let sweep =
        sqnr_sweep(&dist, WORKLOAD_SQNR_SAMPLES, WORKLOAD_SQNR_SEED);
    for (n_e, row) in sweep.iter().enumerate() {
        g.push(format!("sqnr_ne{n_e}_all"), row[0]);
        g.push(format!("sqnr_ne{n_e}_core"), row[1]);
    }

    // trace-driven campaign at the LLM stress format
    let spec = ExperimentSpec {
        id: "trace-ne4".into(),
        fmts: FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1()),
        dist_x: dist,
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr: 32,
        samples: CAMPAIGN_SAMPLES,
        sampler: Default::default(),
    };
    let agg = run_experiment(&RustEngine, &spec, CAMPAIGN_SEED).unwrap();
    assert_eq!(agg.samples() as usize, CAMPAIGN_SAMPLES);
    let cfg = SpecConfig::default();
    let conv = required_enob(&agg, Arch::Conventional, cfg).enob;
    let unit = required_enob(&agg, Arch::GrUnit, cfg).enob;
    g.push("enob_conv", conv);
    g.push("enob_unit", unit);
    g.push("enob_row", required_enob(&agg, Arch::GrRow, cfg).enob);
    g.push("delta_enob", conv - unit);
    g.push("mean_n_eff", agg.mean_n_eff());
    g.push("sqnr_db", agg.sqnr_db());
    g.push("nf_mean", agg.nf.mean());
    g.push("g_unit_ms", agg.g_unit.mean_sq());
    g.check();
}

// ---------------------------------------------------------------------
// Tile mapper — layer-scale GEMM on GR-MAC tiles (rng -> operands ->
// per-tile column MACs -> spec-solved ADCs -> digitized reduction ->
// energy::arch totals), pinned for three configurations: native gr-unit,
// conventional, and a wide format that needs global normalization.
// ---------------------------------------------------------------------

const LAYER_SEED: u64 = 42;
const LAYER_SHAPE: grcim::tile::GemmShape =
    grcim::tile::GemmShape { m: 4, k: 40, n: 40 };
const LAYER_NR: usize = 16;
const LAYER_NC: usize = 16;

#[test]
fn golden_layer_gemm() {
    use grcim::coordinator::CampaignConfig;
    use grcim::distributions::Distribution;
    use grcim::energy::{CimArch, TechParams};
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    use grcim::runtime::EngineKind;
    use grcim::tile::{run_layer, AdcPolicy, LayerSpec, TileConfig};

    let mut g = Golden::new("layer_gemm", 1e-6);
    let fp4 = FpFormat::fp4_e2m1();
    let configs = [
        ("gru", FpFormat::fp(2, 2), CimArch::GrUnit),
        ("conv", FpFormat::fp(2, 2), CimArch::Conventional),
        ("wide", FpFormat::fp(4, 2), CimArch::GrUnit),
    ];
    for (tag, fx, arch) in configs {
        let spec = LayerSpec {
            name: tag.to_string(),
            shape: LAYER_SHAPE,
            cfg: TileConfig {
                nr: LAYER_NR,
                nc: LAYER_NC,
                fmts: FormatPair::new(fx, fp4),
                arch,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(fp4),
        };
        let campaign = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: LAYER_SEED,
            ..Default::default()
        };
        let res = run_layer(&spec, &campaign).unwrap();
        let r = &res.report;
        assert_eq!(r.tiles.len(), 9, "3x3 tile grid");
        for (i, t) in r.tiles.iter().enumerate() {
            g.push(format!("{tag}_tile{i}_enob"), t.enob);
        }
        g.push(format!("{tag}_tiles_fj"), r.tiles_fj);
        g.push(format!("{tag}_reduction_fj"), r.reduction_fj);
        g.push(format!("{tag}_global_norm_fj"), r.global_norm_fj);
        g.push(format!("{tag}_total_fj"), r.total_fj());
        g.push(format!("{tag}_fj_per_mac"), r.fj_per_mac());
        g.push(format!("{tag}_sqnr_db"), r.sqnr_db);
        g.push(
            format!("{tag}_y_abs_sum"),
            res.y.iter().map(|v| v.abs()).sum::<f64>(),
        );
        g.push(
            format!("{tag}_y_sq_sum"),
            res.y.iter().map(|v| v * v).sum::<f64>(),
        );
        g.push(format!("{tag}_enob_mean"), r.enob_mean());
        // the report's own invariant checks (incl. the energy::arch
        // reconciliation the acceptance criteria pin) must hold
        let fr = r.to_figure_result();
        assert!(fr.all_hold(), "{tag}: {:#?}", fr.checks);
    }
    g.check();
}

// ---------------------------------------------------------------------
// Model pipeline — chained tile layers (rng -> operands -> per-layer
// requantization -> tile grids -> float-domain epilogues -> float
// reference chain), pinned for gr-unit and conventional signal chains.
// ---------------------------------------------------------------------

const MODEL_SEED: u64 = 42;
const MODEL_NR: usize = 8;
const MODEL_NC: usize = 8;

#[test]
fn golden_model_report() {
    use grcim::coordinator::CampaignConfig;
    use grcim::distributions::Distribution;
    use grcim::energy::{CimArch, TechParams};
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    use grcim::model::{parse_model, run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    let mut g = Golden::new("model_report", 1e-6);
    let fp4 = FpFormat::fp4_e2m1();
    for (tag, arch) in
        [("gru", CimArch::GrUnit), ("conv", CimArch::Conventional)]
    {
        let spec = ModelSpec {
            name: tag.to_string(),
            layers: parse_model("mlp:24x16x12x8", 4).unwrap(),
            cfg: TileConfig {
                nr: MODEL_NR,
                nc: MODEL_NC,
                fmts: FormatPair::new(FpFormat::fp(2, 2), fp4),
                arch,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(fp4),
            relu: true,
            fit_activations: true,
        };
        let campaign = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: MODEL_SEED,
            ..Default::default()
        };
        let res = run_model(&spec, &campaign).unwrap();
        let r = &res.report;
        assert_eq!(r.layers.len(), 3, "mlp:24x16x12x8 is 3 layers");
        for (li, l) in r.layers.iter().enumerate() {
            g.push(format!("{tag}_l{li}_enob_mean"), l.report.enob_mean());
            g.push(format!("{tag}_l{li}_total_fj"), l.report.total_fj());
            g.push(format!("{tag}_l{li}_sqnr_db"), l.report.sqnr_db);
            g.push(format!("{tag}_l{li}_requant_db"), l.requant_sqnr_db);
            g.push(format!("{tag}_l{li}_a_scale"), l.a_scale);
            let s = l.act_stats.expect("fit_activations was requested");
            g.push(format!("{tag}_l{li}_act_dr_bits"), s.dr_bits);
            g.push(format!("{tag}_l{li}_act_sigma_core"), s.sigma_core);
            g.push(format!("{tag}_l{li}_act_outlier_mass"), s.outlier_mass);
        }
        g.push(format!("{tag}_total_fj"), r.total_fj());
        g.push(format!("{tag}_fj_per_mac"), r.fj_per_mac());
        g.push(format!("{tag}_e2e_sqnr_db"), r.sqnr_db);
        g.push(
            format!("{tag}_y_abs_sum"),
            res.y.iter().map(|v| v.abs()).sum::<f64>(),
        );
        g.push(
            format!("{tag}_y_sq_sum"),
            res.y.iter().map(|v| v * v).sum::<f64>(),
        );
        g.push(format!("{tag}_enob_mean"), r.enob_mean());
        // the report's own invariant checks (incl. the energy::arch
        // reconciliation the acceptance criteria pin) must hold
        let fr = r.to_figure_result();
        assert!(fr.all_hold(), "{tag}: {:#?}", fr.checks);
    }
    g.check();
}

// ---------------------------------------------------------------------
// Attention — the transformer/decode presets through the real
// QK^T / softmax / A·V stage (1-head and 4-head prefill blocks plus a
// KV-cache decode GEMV), pinned per layer, per attention sub-GEMM, and
// per model against the twin's attn_twin.
// ---------------------------------------------------------------------

const ATTN_SEED: u64 = 77;
const ATTN_NR: usize = 16;
const ATTN_NC: usize = 16;
const ATTN_TOKENS: usize = 4;

#[test]
fn golden_attention_block() {
    use grcim::coordinator::CampaignConfig;
    use grcim::distributions::Distribution;
    use grcim::energy::{CimArch, TechParams};
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    use grcim::model::{parse_model, run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    let mut g = Golden::new("attention_block", 1e-6);
    let fp4 = FpFormat::fp4_e2m1();
    let cases = [
        ("t1", "transformer:64x1x2", ATTN_TOKENS, 1usize),
        ("t4", "transformer:64x4x2", ATTN_TOKENS, 4),
        ("dec", "decode:64x4x32", 1, 4),
    ];
    for (ctag, model, tokens, heads) in cases {
        for (atag, arch) in
            [("gru", CimArch::GrUnit), ("cnv", CimArch::Conventional)]
        {
            let tag = format!("{ctag}_{atag}");
            let spec = ModelSpec {
                name: tag.clone(),
                layers: parse_model(model, tokens).unwrap(),
                cfg: TileConfig {
                    nr: ATTN_NR,
                    nc: ATTN_NC,
                    fmts: FormatPair::new(FpFormat::fp(4, 2), fp4),
                    arch,
                    adc: AdcPolicy::PerTileSpec,
                    tech: TechParams::default(),
                },
                dist_x: Distribution::gauss_outliers(),
                dist_w: Distribution::max_entropy(fp4),
                relu: false,
                fit_activations: false,
            };
            let campaign = CampaignConfig {
                engine: EngineKind::Rust,
                workers: 2,
                seed: ATTN_SEED,
                ..Default::default()
            };
            let res = run_model(&spec, &campaign).unwrap();
            let r = &res.report;
            for (li, l) in r.layers.iter().enumerate() {
                g.push(format!("{tag}_l{li}_enob_mean"), l.report.enob_mean());
                g.push(format!("{tag}_l{li}_total_fj"), l.report.total_fj());
                g.push(format!("{tag}_l{li}_sqnr_db"), l.report.sqnr_db);
                g.push(format!("{tag}_l{li}_requant_db"), l.requant_sqnr_db);
                if let Some(sm) = l.softmax_requant_db {
                    g.push(format!("{tag}_l{li}_softmax_db"), sm);
                    // per-sub-GEMM ADC means: the combined report indexes
                    // tiles by kt = sub-GEMM (QK^T heads, then A·V heads)
                    for sub in 0..2 * heads {
                        let (mut s, mut c) = (0.0f64, 0usize);
                        for t in l.report.tiles.iter().filter(|t| t.kt == sub)
                        {
                            s += t.enob;
                            c += 1;
                        }
                        assert!(c > 0, "{tag} l{li}: empty sub-GEMM {sub}");
                        g.push(
                            format!("{tag}_l{li}_sub{sub}_enob"),
                            s / c as f64,
                        );
                    }
                }
            }
            g.push(format!("{tag}_total_fj"), r.total_fj());
            g.push(format!("{tag}_fj_per_mac"), r.fj_per_mac());
            g.push(format!("{tag}_fj_per_token"), r.fj_per_token());
            g.push(format!("{tag}_e2e_sqnr_db"), r.sqnr_db);
            g.push(
                format!("{tag}_y_abs_sum"),
                res.y.iter().map(|v| v.abs()).sum::<f64>(),
            );
            g.push(
                format!("{tag}_y_sq_sum"),
                res.y.iter().map(|v| v * v).sum::<f64>(),
            );
            g.push(format!("{tag}_enob_mean"), r.enob_mean());
            // the virtual M x (2S) x d attention shape keeps the energy
            // reconciliation and MAC-coverage invariants intact
            let fr = r.to_figure_result();
            assert!(fr.all_hold(), "{tag}: {:#?}", fr.checks);
        }
    }
    g.check();
}

// ---------------------------------------------------------------------
// Convolution — a conv-led chain through the im2col flattener onto the
// unchanged weight-stationary mapper, pinned against the twin's
// im2col_twin path.
// ---------------------------------------------------------------------

const CONV_SEED: u64 = 91;
const CONV_NR: usize = 8;
const CONV_NC: usize = 8;

#[test]
fn golden_conv_im2col() {
    use grcim::coordinator::CampaignConfig;
    use grcim::distributions::Distribution;
    use grcim::energy::{CimArch, TechParams};
    use grcim::formats::FpFormat;
    use grcim::mac::FormatPair;
    use grcim::model::{parse_model, run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    let mut g = Golden::new("conv_im2col", 1e-6);
    let fp4 = FpFormat::fp4_e2m1();
    for (tag, arch) in
        [("gru", CimArch::GrUnit), ("cnv", CimArch::Conventional)]
    {
        let spec = ModelSpec {
            name: tag.to_string(),
            layers: parse_model("conv:6x3x3x3@8x8,gemm:36x6x4", 1).unwrap(),
            cfg: TileConfig {
                nr: CONV_NR,
                nc: CONV_NC,
                fmts: FormatPair::new(FpFormat::fp(2, 2), fp4),
                arch,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(fp4),
            relu: true,
            fit_activations: false,
        };
        let campaign = CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: CONV_SEED,
            ..Default::default()
        };
        let res = run_model(&spec, &campaign).unwrap();
        let r = &res.report;
        assert_eq!(r.layers.len(), 2, "conv + head GEMM");
        for (li, l) in r.layers.iter().enumerate() {
            g.push(format!("{tag}_l{li}_enob_mean"), l.report.enob_mean());
            g.push(format!("{tag}_l{li}_total_fj"), l.report.total_fj());
            g.push(format!("{tag}_l{li}_sqnr_db"), l.report.sqnr_db);
            g.push(format!("{tag}_l{li}_requant_db"), l.requant_sqnr_db);
            g.push(format!("{tag}_l{li}_a_scale"), l.a_scale);
        }
        g.push(format!("{tag}_total_fj"), r.total_fj());
        g.push(format!("{tag}_fj_per_mac"), r.fj_per_mac());
        g.push(format!("{tag}_e2e_sqnr_db"), r.sqnr_db);
        g.push(
            format!("{tag}_y_abs_sum"),
            res.y.iter().map(|v| v.abs()).sum::<f64>(),
        );
        g.push(
            format!("{tag}_y_sq_sum"),
            res.y.iter().map(|v| v * v).sum::<f64>(),
        );
        g.push(format!("{tag}_enob_mean"), r.enob_mean());
        let fr = r.to_figure_result();
        assert!(fr.all_hold(), "{tag}: {:#?}", fr.checks);
    }
    g.check();
}

// ---------------------------------------------------------------------
// Design-space Pareto explorer: the full pipeline (plan expansion ->
// seeded operands -> tile campaign -> component breakdown -> digital
// baseline -> frontier), pinned per point against the twin.
// ---------------------------------------------------------------------

/// TOML equivalent of the twin's `PARETO_PLAN` (defaults supply
/// distribution, adc, adc_scale).
const PARETO_PLAN_TOML: &str = r#"
name = "golden"
seed = 42
tokens = 4

[axes]
workload = "gemm:4x32x8"
nr = [8, 16]
nc = 8
arch = ["gr-unit", "conventional"]
n_e = [2, 4]
n_m = 2
"#;

#[test]
fn golden_pareto_explore() {
    use grcim::coordinator::CampaignConfig;
    use grcim::explore::{run_fresh, ParetoPlan};
    use grcim::runtime::EngineKind;

    let mut g = Golden::new("pareto_explore", 1e-6);
    let plan = ParetoPlan::from_toml(PARETO_PLAN_TOML).unwrap();
    let h = plan.content_hash();
    g.push("plan_hash_hi", (h >> 32) as f64);
    g.push("plan_hash_lo", (h & 0xFFFF_FFFF) as f64);
    let campaign = CampaignConfig {
        engine: EngineKind::Rust,
        workers: 2,
        seed: 42,
        ..Default::default()
    };
    let out = run_fresh(&plan, &campaign).unwrap();
    assert_eq!(out.points.len(), plan.num_points());
    g.push("num_points", out.points.len() as f64);
    g.push("num_frontier", out.frontier_points().len() as f64);
    for (p, &front) in out.points.iter().zip(&out.frontier) {
        let i = p.index;
        // the acceptance invariant: breakdown sums to total within 1e-9
        assert!(
            p.breakdown_reconciles(),
            "point {i}: breakdown sum {} vs total {}",
            p.breakdown_sum(),
            p.total_fj
        );
        g.push(format!("p{i}_enob_mean"), p.enob_mean);
        g.push(format!("p{i}_sqnr_db"), p.sqnr_db);
        g.push(format!("p{i}_adc_fj"), p.adc_fj);
        g.push(format!("p{i}_dac_fj"), p.dac_fj);
        g.push(format!("p{i}_cells_fj"), p.cells_fj);
        g.push(format!("p{i}_exp_logic_fj"), p.exp_logic_fj);
        g.push(format!("p{i}_tree_fj"), p.tree_fj);
        g.push(format!("p{i}_norm_mult_fj"), p.norm_mult_fj);
        g.push(format!("p{i}_reduction_fj"), p.reduction_fj);
        g.push(format!("p{i}_global_norm_fj"), p.global_norm_fj);
        g.push(format!("p{i}_softmax_fj"), p.softmax_fj);
        g.push(format!("p{i}_total_fj"), p.total_fj);
        g.push(format!("p{i}_fj_per_mac"), p.fj_per_mac);
        g.push(format!("p{i}_digital_fj_per_mac"), p.digital_fj_per_mac);
        g.push(format!("p{i}_digital_ratio"), p.digital_ratio);
        if let Some(x) = p.crossover_enob {
            g.push(format!("p{i}_crossover_enob"), x);
        }
        g.push(format!("p{i}_frontier"), if front { 1.0 } else { 0.0 });
    }
    g.check();
}

// ---------------------------------------------------------------------
// Determinism + harness self-tests.
// ---------------------------------------------------------------------

#[test]
fn golden_campaign_is_deterministic_run_to_run() {
    use grcim::coordinator::run_experiment;
    use grcim::runtime::RustEngine;
    // two in-process runs of the same campaign must agree bit-for-bit —
    // the property the snapshot files rely on
    let specs = campaign_specs();
    let spec = &specs[0];
    let a = run_experiment(&RustEngine, spec, CAMPAIGN_SEED).unwrap();
    let b = run_experiment(&RustEngine, spec, CAMPAIGN_SEED).unwrap();
    assert_eq!(a.nf.sum.to_bits(), b.nf.sum.to_bits());
    assert_eq!(a.sig.sum_sq.to_bits(), b.sig.sum_sq.to_bits());
    assert_eq!(a.n_eff.sum.to_bits(), b.n_eff.sum.to_bits());
}

#[test]
fn golden_compare_detects_perturbation_and_key_drift() {
    let golden: BTreeMap<String, f64> =
        [("a".to_string(), 1.0), ("b".to_string(), 20.0)].into();
    // identical values pass
    let ok = vec![("a".to_string(), 1.0 + 1e-12), ("b".to_string(), 20.0)];
    assert!(compare(&golden, &ok, 1e-9).is_ok());
    // a perturbed spec constant fails
    let drift = vec![("a".to_string(), 1.01), ("b".to_string(), 20.0)];
    let errs = compare(&golden, &drift, 1e-9).unwrap_err();
    assert_eq!(errs.len(), 1);
    assert!(errs[0].contains("'a'") || errs[0].contains("a:"), "{errs:?}");
    // missing and extra keys fail
    let missing = vec![("a".to_string(), 1.0)];
    assert!(compare(&golden, &missing, 1e-9).is_err());
    let extra = vec![
        ("a".to_string(), 1.0),
        ("b".to_string(), 20.0),
        ("c".to_string(), 3.0),
    ];
    assert!(compare(&golden, &extra, 1e-9).is_err());
}
