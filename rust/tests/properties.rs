//! Property-based invariant suite over the whole stack, driven by the
//! in-repo `propcheck` kit (no proptest in the vendor set).

use grcim::analog::GrMacCell;
use grcim::distributions::Distribution;
use grcim::energy::{energy_per_op, CimArch, TechParams};
use grcim::formats::FpFormat;
use grcim::mac::{adc_quantize, simulate_column, FormatPair};
use grcim::propcheck::{check_simple, ensure};
use grcim::rng::Pcg64;
use grcim::spec::{required_enob, Arch, SpecConfig};
use grcim::stats::ColumnAgg;

fn rand_fmt(rng: &mut Pcg64) -> FpFormat {
    FpFormat::fp(1 + rng.below(5) as u32, 1 + rng.below(5) as u32)
}

#[derive(Debug, Clone)]
struct Case {
    fmts: FormatPair,
    nr: usize,
    x: Vec<f64>,
    w: Vec<f64>,
}

fn rand_case(rng: &mut Pcg64) -> Case {
    let nr = [4usize, 8, 16, 32][rng.below(4) as usize];
    let b = 8;
    let fmts = FormatPair::new(rand_fmt(rng), rand_fmt(rng));
    let dist = match rng.below(3) {
        0 => Distribution::Uniform,
        1 => Distribution::clipped_gauss4(),
        _ => Distribution::gauss_outliers(),
    };
    let mut x = vec![0.0; b * nr];
    let mut w = vec![0.0; b * nr];
    dist.fill(rng, &mut x);
    Distribution::Uniform.fill(rng, &mut w);
    Case { fmts, nr, x, w }
}

#[test]
fn prop_linear_chain_identities() {
    check_simple("linear chain", 101, 150, rand_case, |c| {
        let b = simulate_column(&c.x, &c.w, c.nr, c.fmts);
        for i in 0..b.len() {
            let conv = b.v_conv[i] * b.g_conv[i];
            let gr = b.v_gr[i] * b.s_sum[i] / c.nr as f64;
            ensure(
                (conv - b.z_q[i]).abs() < 1e-9,
                || format!("conv path sample {i}: {conv} vs {}", b.z_q[i]),
            )?;
            ensure(
                (gr - b.z_q[i]).abs() < 1e-9,
                || format!("gr path sample {i}: {gr} vs {}", b.z_q[i]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_adc_inputs_and_gains_bounded() {
    check_simple("bounded signals", 102, 150, rand_case, |c| {
        let b = simulate_column(&c.x, &c.w, c.nr, c.fmts);
        for i in 0..b.len() {
            ensure(b.v_conv[i].abs() <= 1.0 + 1e-12, || "v_conv".into())?;
            ensure(b.v_gr[i].abs() <= 1.0 + 1e-12, || "v_gr".into())?;
            ensure(b.g_conv[i] > 0.0 && b.g_conv[i] <= 1.0 + 1e-12, || {
                "g_conv".into()
            })?;
            ensure(
                b.s_sum[i] > 0.0 && b.s_sum[i] <= c.nr as f64 + 1e-9,
                || "s_sum".into(),
            )?;
            let neff = b.s_sum[i] * b.s_sum[i] / b.s2_sum[i];
            ensure(
                (1.0 - 1e-9..=c.nr as f64 + 1e-9).contains(&neff),
                || format!("n_eff {neff}"),
            )?;
            ensure(b.nf[i] >= 0.0, || "nf".into())?;
            ensure(
                (0.0..=1.0 + 1e-12).contains(&b.wq2_mean[i]),
                || "wq2_mean".into(),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_round_trip_under_all_formats() {
    let mut rng = Pcg64::seeded(103);
    for _ in 0..40 {
        let fmt = rand_fmt(&mut rng);
        check_simple(
            "quantizer",
            rng.next_u64(),
            100,
            |r| r.uniform_in(-2.0, 2.0),
            |&x| {
                let q = fmt.quantize(x);
                ensure(fmt.quantize(q) == q, || {
                    format!("{fmt}: not idempotent at {x}")
                })?;
                ensure(q.abs() <= fmt.vmax() + 1e-15, || "exceeds vmax".into())?;
                ensure(
                    fmt.quantize(-x) == -q,
                    || format!("{fmt}: not odd at {x}"),
                )?;
                if x.abs() < fmt.vmax() {
                    let err = (q - x).abs();
                    let lim = 0.5 * fmt.ulp(q.abs()) + 1e-15;
                    ensure(err <= lim, || {
                        format!("{fmt}: err {err} > half-ulp {lim} at {x}")
                    })?;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_adc_quantize_is_monotone_and_bounded() {
    check_simple(
        "adc quantize",
        104,
        300,
        |r| {
            (
                r.uniform_in(-1.2, 1.2),
                r.uniform_in(-1.2, 1.2),
                1.0 + r.uniform() * 14.0,
            )
        },
        |&(a, b, enob)| {
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            let ql = adc_quantize(lo, enob);
            let qh = adc_quantize(hi, enob);
            ensure(ql <= qh, || format!("not monotone at enob {enob}"))?;
            ensure(ql.abs() <= 1.0 && qh.abs() <= 1.0, || "exceeds FS".into())?;
            let delta = 2.0 / 2f64.powf(enob);
            if hi.abs() < 1.0 - delta {
                ensure(
                    (qh - hi).abs() <= 0.5 * delta + 1e-12,
                    || format!("err beyond half-step at enob {enob}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spec_solver_orderings() {
    // for any sampled aggregate: unit <= row <= conventional ENOB, and all
    // finite/positive
    check_simple("spec ordering", 105, 60, rand_case, |c| {
        // need enough samples for stable moments
        let mut rng = Pcg64::seeded(c.nr as u64 + 7);
        let mut x = vec![0.0; 512 * c.nr];
        let mut w = vec![0.0; 512 * c.nr];
        Distribution::clipped_gauss4().fill(&mut rng, &mut x);
        Distribution::Uniform.fill(&mut rng, &mut w);
        let b = simulate_column(&x, &w, c.nr, c.fmts);
        let mut agg = ColumnAgg::new(c.nr);
        agg.push_batch(&b);
        let cfg = SpecConfig::default();
        let conv = required_enob(&agg, Arch::Conventional, cfg).enob;
        let unit = required_enob(&agg, Arch::GrUnit, cfg).enob;
        let row = required_enob(&agg, Arch::GrRow, cfg).enob;
        ensure(conv.is_finite() && unit.is_finite() && row.is_finite(), || {
            "non-finite enob".into()
        })?;
        ensure(unit <= row + 1e-9, || format!("unit {unit} > row {row}"))?;
        ensure(row <= conv + 1e-9, || format!("row {row} > conv {conv}"))?;
        Ok(())
    });
}

#[test]
fn prop_energy_model_monotonicity() {
    check_simple(
        "energy monotone",
        106,
        200,
        |r| {
            (
                FormatPair::new(rand_fmt(r), rand_fmt(r)),
                4.0 + r.uniform() * 8.0,
                [
                    CimArch::Conventional,
                    CimArch::GrUnit,
                    CimArch::GrRow,
                    CimArch::GrInt,
                ][r.below(4) as usize],
            )
        },
        |&(fmts, enob, arch)| {
            let t = TechParams::default();
            let e1 = energy_per_op(arch, fmts, 32, 32, enob, &t).total();
            let e2 = energy_per_op(arch, fmts, 32, 32, enob + 1.0, &t).total();
            ensure(e2 > e1, || format!("{arch:?} not monotone in enob"))?;
            ensure(e1 > 0.0, || "non-positive energy".into())?;
            // deeper arrays amortize converters: ADC per-op shrinks
            let d1 = energy_per_op(arch, fmts, 64, 32, enob, &t);
            let s1 = energy_per_op(arch, fmts, 32, 32, enob, &t);
            ensure(d1.adc < s1.adc, || "adc not amortized by depth".into())?;
            Ok(())
        },
    );
}

#[test]
fn prop_capnet_cell_linearity_under_random_design() {
    check_simple(
        "cell linearity",
        107,
        60,
        |r| {
            (
                GrMacCell::design(
                    3 + r.below(3) as usize,
                    3 + r.below(2) as usize,
                    0.5 + r.uniform() * 2.0,
                    r.uniform() * 1.5,
                ),
                r.uniform_in(0.1, 1.0),
            )
        },
        |(cell, v_in)| {
            for level in 1..=cell.levels() {
                let q0 = cell.transfer_closed_form(0, level, *v_in);
                let q1 = cell.transfer_closed_form(1, level, *v_in);
                let lsb = q1 - q0;
                ensure(lsb > 0.0, || "non-positive LSB".into())?;
                for w in [2u64, 3, cell.m_codes() - 1] {
                    let q = cell.transfer_closed_form(w, level, *v_in);
                    ensure(
                        (q - q0 - w as f64 * lsb).abs()
                            < 1e-9 * q.abs().max(1.0),
                        || format!("nonlinear at level {level} w {w}"),
                    )?;
                }
            }
            // octave gains (design is compensated for its own c_p1)
            let top = cell.m_codes() - 1;
            for level in 2..=cell.levels() {
                let r = cell.transfer_closed_form(top, level, *v_in)
                    / cell.transfer_closed_form(top, level - 1, *v_in);
                ensure(
                    (r - 2.0).abs() < 1e-9,
                    || format!("gain ratio {r} at level {level}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adc_quantize_idempotent_at_integer_enob() {
    // For integer ENOB the step divides full scale exactly, so every ADC
    // output (including the clamped +/-1 rails) is a fixed point. (For
    // fractional ENOB the rail codes are not representable, so only
    // monotonicity is guaranteed — see prop_adc_quantize_is_monotone.)
    check_simple(
        "adc idempotent",
        108,
        400,
        |r| (r.uniform_in(-2.0, 2.0), 1.0 + r.below(14) as f64),
        |&(v, enob)| {
            let q = adc_quantize(v, enob);
            let qq = adc_quantize(q, enob);
            ensure(qq == q, || {
                format!("adc(adc({v})) = {qq} != {q} at enob {enob}")
            })?;
            ensure(q.abs() <= 1.0, || "output beyond full scale".into())
        },
    );
}

#[test]
fn prop_energy_components_nonnegative_and_total_positive() {
    check_simple(
        "energy nonnegative",
        109,
        300,
        |r| {
            (
                FormatPair::new(rand_fmt(r), rand_fmt(r)),
                0.5 + r.uniform() * 13.5,
                [
                    CimArch::Conventional,
                    CimArch::GrUnit,
                    CimArch::GrRow,
                    CimArch::GrInt,
                ][r.below(4) as usize],
                8usize << r.below(4), // nr in {8,16,32,64}
                8usize << r.below(4),
            )
        },
        |&(fmts, enob, arch, nr, nc)| {
            let t = TechParams::default();
            let b = energy_per_op(arch, fmts, nr, nc, enob, &t);
            for (name, v) in b.components() {
                ensure(v >= 0.0 && v.is_finite(), || {
                    format!("{arch:?} component {name} = {v}")
                })?;
            }
            ensure(b.total() > 0.0, || format!("{arch:?} total {}", b.total()))
        },
    );
}

#[test]
fn prop_energy_monotone_in_enob_for_every_arch() {
    // strict monotonicity in ENOB, separately per architecture (the
    // existing mixed-arch property samples; this one sweeps a ladder)
    for arch in [
        CimArch::Conventional,
        CimArch::GrUnit,
        CimArch::GrRow,
        CimArch::GrInt,
    ] {
        let t = TechParams::default();
        let fmts =
            FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
        let mut prev = 0.0;
        for step in 0..20 {
            let enob = 1.0 + step as f64 * 0.65;
            let e = energy_per_op(arch, fmts, 32, 32, enob, &t).total();
            assert!(
                e > prev,
                "{arch:?}: energy not monotone at enob {enob}: {e} <= {prev}"
            );
            prev = e;
        }
    }
}

#[test]
fn coordinator_bit_identical_aggregates_across_1_2_4_workers() {
    use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
    use grcim::runtime::EngineKind;
    // every aggregate field, not just one moment, must be bit-identical
    // regardless of worker count (same seeds => same ColumnAgg)
    fn agg_bits(a: &ColumnAgg) -> Vec<u64> {
        let mut out = Vec::new();
        for m in [
            &a.sig, &a.qerr, &a.nf, &a.wq2, &a.g_conv, &a.g_unit, &a.g_row,
            &a.n_eff, &a.v_conv, &a.v_gr,
        ] {
            out.push(m.n);
            out.push(m.sum.to_bits());
            out.push(m.sum_sq.to_bits());
        }
        out
    }
    let specs = vec![
        ExperimentSpec {
            id: "det-a".into(),
            fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
            dist_x: Distribution::Uniform,
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 32,
            samples: 4096,
            sampler: Default::default(),
        },
        ExperimentSpec {
            id: "det-b".into(),
            fmts: FormatPair::new(FpFormat::fp(4, 2), FpFormat::fp4_e2m1()),
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 16,
            samples: 6144,
            sampler: Default::default(),
        },
    ];
    let mut reference: Option<Vec<Vec<u64>>> = None;
    for workers in [1usize, 2, 4] {
        let cfg = CampaignConfig {
            engine: EngineKind::Rust,
            workers,
            seed: 0xDEC0DE,
            ..Default::default()
        };
        let aggs = run_campaign(&specs, &cfg).unwrap();
        let bits: Vec<Vec<u64>> = aggs.iter().map(agg_bits).collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => {
                assert_eq!(r, &bits, "workers={workers} changed aggregates")
            }
        }
    }
}

#[test]
fn prop_campaign_seeding_is_scheduling_invariant() {
    use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
    use grcim::runtime::EngineKind;
    let spec = ExperimentSpec {
        id: "prop".into(),
        fmts: FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1()),
        dist_x: Distribution::Uniform,
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        nr: 16,
        samples: 6144,
        sampler: Default::default(),
    };
    let mut reference: Option<u64> = None;
    for workers in [1usize, 2, 5, 9] {
        let cfg = CampaignConfig {
            engine: EngineKind::Rust,
            workers,
            seed: 1234,
            ..Default::default()
        };
        let aggs = run_campaign(&[spec.clone()], &cfg).unwrap();
        let bits = aggs[0].nf.sum.to_bits();
        match reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(r, bits, "workers={workers} changed results"),
        }
    }
}

#[test]
fn prop_tiled_gemm_with_high_resolution_adc_matches_float_reference() {
    use grcim::rng::Pcg64;
    use grcim::runtime::RustEngine;
    use grcim::tile::{gemm_with_engine, AdcPolicy, GemmShape, TileConfig};

    // max-entropy operands are exactly representable, so with a
    // near-transparent ADC the tiled GEMM must reproduce the float
    // matmul reference to reduction-tree rounding (the satellite's
    // tile-mapper correctness property)
    let mut rng = Pcg64::seeded(0x71C0);
    for case in 0..12 {
        let shape = GemmShape {
            m: 1 + rng.below(4) as usize,
            k: 1 + rng.below(48) as usize,
            n: 1 + rng.below(12) as usize,
        };
        let nr = [4usize, 8, 16, 32][rng.below(4) as usize];
        let nc = [2usize, 4, 8][rng.below(3) as usize];
        let fmts = FormatPair::new(FpFormat::fp(2, 3), FpFormat::fp4_e2m1());
        let cfg = TileConfig {
            nr,
            nc,
            fmts,
            arch: if case % 2 == 0 { CimArch::GrUnit } else { CimArch::Conventional },
            adc: AdcPolicy::Fixed(40.0),
            tech: TechParams::default(),
        };
        let mut x = vec![0.0f32; shape.m * shape.k];
        Distribution::max_entropy(fmts.x).fill_f32(&mut rng, &mut x);
        let mut wt = vec![0.0f32; shape.n * shape.k];
        Distribution::max_entropy(fmts.w).fill_f32(&mut rng, &mut wt);
        let res = gemm_with_engine(&RustEngine, "prop", &cfg, shape, &x, &wt).unwrap();
        for m in 0..shape.m {
            for n in 0..shape.n {
                let mut r = 0.0f64;
                for k in 0..shape.k {
                    r += x[m * shape.k + k] as f64 * wt[n * shape.k + k] as f64;
                }
                let got = res.y[m * shape.n + n];
                assert!(
                    (got - r).abs() < 1e-9,
                    "case {case} {shape} nr={nr} nc={nc}: y[{m},{n}] = {got} vs {r}"
                );
            }
        }
    }
}

#[test]
fn prop_tile_layer_bit_identical_across_1_2_4_workers() {
    use grcim::coordinator::CampaignConfig;
    use grcim::runtime::EngineKind;
    use grcim::tile::{run_layer, AdcPolicy, GemmShape, LayerSpec, TileConfig};

    // the satellite's second property: layer aggregates (per-tile ENOBs,
    // energy totals, outputs) are bit-identical at any worker count
    let spec = LayerSpec {
        name: "det".into(),
        shape: GemmShape { m: 3, k: 40, n: 18 },
        cfg: TileConfig {
            nr: 16,
            nc: 8,
            fmts: FormatPair::new(FpFormat::fp(3, 2), FpFormat::fp4_e2m1()),
            arch: CimArch::GrRow,
            adc: AdcPolicy::PerTileSpec,
            tech: TechParams::default(),
        },
        dist_x: Distribution::gauss_outliers(),
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        conv: None,
    };
    let mut reference: Option<(Vec<u64>, Vec<u64>, u64)> = None;
    for workers in [1usize, 2, 4] {
        let cfg = CampaignConfig {
            engine: EngineKind::Rust,
            workers,
            seed: 0x7AB5,
            ..Default::default()
        };
        let res = run_layer(&spec, &cfg).unwrap();
        let y_bits: Vec<u64> = res.y.iter().map(|v| v.to_bits()).collect();
        let enob_bits: Vec<u64> =
            res.report.tiles.iter().map(|t| t.enob.to_bits()).collect();
        let bits = (y_bits, enob_bits, res.report.total_fj().to_bits());
        match &reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(r, &bits, "workers={workers} changed the layer"),
        }
    }
}

#[test]
fn prop_model_bit_identical_across_1_2_4_workers() {
    use grcim::coordinator::CampaignConfig;
    use grcim::model::{run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    // the model-scale acceptance property: chained layer evaluations
    // (per-tile ENOBs, energy totals, requantization SQNRs, outputs,
    // end-to-end SQNR) are bit-identical at any worker count
    let spec = ModelSpec {
        name: "det".into(),
        layers: grcim::model::parse_model("mlp:24x16x12x8", 3).unwrap(),
        cfg: TileConfig {
            nr: 8,
            nc: 4,
            fmts: FormatPair::new(FpFormat::fp(2, 2), FpFormat::fp4_e2m1()),
            arch: CimArch::GrUnit,
            adc: AdcPolicy::PerTileSpec,
            tech: TechParams::default(),
        },
        dist_x: Distribution::gauss_outliers(),
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        relu: true,
        fit_activations: true,
    };
    let mut reference: Option<(Vec<u64>, Vec<u64>, u64, u64)> = None;
    for workers in [1usize, 2, 4] {
        let cfg = CampaignConfig {
            engine: EngineKind::Rust,
            workers,
            seed: 0x30DE,
            ..Default::default()
        };
        let res = run_model(&spec, &cfg).unwrap();
        let y_bits: Vec<u64> = res.y.iter().map(|v| v.to_bits()).collect();
        let layer_bits: Vec<u64> = res
            .report
            .layers
            .iter()
            .flat_map(|l| {
                let mut bits: Vec<u64> =
                    l.report.tiles.iter().map(|t| t.enob.to_bits()).collect();
                bits.push(l.report.total_fj().to_bits());
                bits.push(l.requant_sqnr_db.to_bits());
                bits
            })
            .collect();
        let bits = (
            y_bits,
            layer_bits,
            res.report.sqnr_db.to_bits(),
            res.report.total_fj().to_bits(),
        );
        match &reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(r, &bits, "workers={workers} changed the model"),
        }
    }
}

#[test]
fn prop_sampler_pooled_aggregates_bit_identical_across_1_2_4_workers() {
    use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
    use grcim::distributions::Sampler;
    use grcim::runtime::EngineKind;
    // the worker-count invariance the Plain mode has always had must
    // carry over to every estimator mode: a job's slab is a pure
    // function of its seed, so pooling order is the only degree of
    // freedom — and pooling is per-job deterministic
    fn agg_bits(a: &ColumnAgg) -> Vec<u64> {
        let mut out = Vec::new();
        for m in [
            &a.sig, &a.qerr, &a.nf, &a.wq2, &a.g_conv, &a.g_unit, &a.g_row,
            &a.n_eff, &a.v_conv, &a.v_gr,
        ] {
            out.push(m.n);
            out.push(m.sum.to_bits());
            out.push(m.sum_sq.to_bits());
        }
        out
    }
    for sampler in Sampler::ALL {
        let specs = vec![ExperimentSpec {
            id: format!("det-{}", sampler.name()),
            fmts: FormatPair::new(FpFormat::fp(4, 3), FpFormat::fp4_e2m1()),
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            nr: 16,
            samples: 6144,
            sampler,
        }];
        let mut reference: Option<Vec<Vec<u64>>> = None;
        for workers in [1usize, 2, 4] {
            let cfg = CampaignConfig {
                engine: EngineKind::Rust,
                workers,
                seed: 0x5A3,
                ..Default::default()
            };
            let aggs = run_campaign(&specs, &cfg).unwrap();
            let bits: Vec<Vec<u64>> = aggs.iter().map(agg_bits).collect();
            match &reference {
                None => reference = Some(bits),
                Some(r) => assert_eq!(
                    r,
                    &bits,
                    "{}: workers={workers} changed aggregates",
                    sampler.name()
                ),
            }
        }
    }
}

#[test]
fn prop_samplers_preserve_mean_and_variance() {
    use grcim::distributions::Sampler;
    use grcim::workload::{EmpiricalDist, TensorTrace};
    // every estimator mode draws the same marginal law per element, so
    // slab mean/variance must agree across modes to Monte-Carlo noise —
    // on both the analytic stress mixture and a fitted empirical trace
    let mut trng = Pcg64::seeded(0x7ACE);
    let mut raw = vec![0.0f32; 4096];
    Distribution::gauss_outliers().fill_f32(&mut trng, &mut raw);
    let trace = TensorTrace::from_f32("prop", vec![raw.len()], raw).unwrap();
    let dists = [
        Distribution::gauss_outliers(),
        Distribution::empirical(EmpiricalDist::fit(&trace).unwrap()),
    ];
    let (rows, row_len) = (8192usize, 8usize);
    for (di, dist) in dists.iter().enumerate() {
        let mut stats = Vec::new();
        for sampler in Sampler::ALL {
            let mut rng = Pcg64::seeded(0xBEEF + di as u64);
            let mut slab = vec![0.0f32; rows * row_len];
            sampler.fill_slab_f32(dist, &mut rng, &mut slab, row_len);
            let n = slab.len() as f64;
            let mean = slab.iter().map(|v| *v as f64).sum::<f64>() / n;
            let var = slab
                .iter()
                .map(|v| (*v as f64 - mean) * (*v as f64 - mean))
                .sum::<f64>()
                / n;
            stats.push((mean, var));
        }
        let (m0, v0) = stats[0];
        for &(m, v) in &stats[1..] {
            // mean tolerance: a few sigma of the plain-mode standard
            // error; variance agrees relatively
            assert!(
                (m - m0).abs() < 5.0 * (v0 / (rows * row_len) as f64).sqrt(),
                "dist {di}: means diverged {stats:?}"
            );
            assert!(
                (v - v0).abs() < 0.15 * v0,
                "dist {di}: variances diverged {stats:?}"
            );
        }
    }
}

#[test]
fn prop_antithetic_pairs_mirror_magnitudes_and_keep_signs() {
    use grcim::distributions::Sampler;
    // the pair construction: same sign, magnitude quantiles summing to
    // the full range — exact for the uniform quantile map (up to one
    // f32 rounding each)
    for (rows, row_len) in [(8usize, 4usize), (64, 16), (127, 8)] {
        let mut rng = Pcg64::seeded(rows as u64);
        let mut slab = vec![0.0f32; rows * row_len];
        Sampler::Antithetic.fill_slab_f32(
            &Distribution::Uniform,
            &mut rng,
            &mut slab,
            row_len,
        );
        for p in 0..rows / 2 {
            for i in 0..row_len {
                let a = slab[2 * p * row_len + i] as f64;
                let b = slab[(2 * p + 1) * row_len + i] as f64;
                assert!(a * b >= 0.0, "pair {p}[{i}] flipped sign: {a} {b}");
                assert!(
                    (a.abs() + b.abs() - 1.0).abs() < 1e-6,
                    "pair {p}[{i}] not mirrored: {a} {b}"
                );
            }
        }
    }
}

#[test]
fn prop_softmax_rows_normalize_and_are_permutation_equivariant() {
    use grcim::model::softmax_rows_f32;
    // rows sum to 1 (to f32 summation accuracy), probabilities are
    // nonnegative, and rotating a row's scores rotates its
    // probabilities — softmax has no positional preference (only the
    // f32 summation order changes, a ~1-ulp-per-term effect)
    let mut rng = Pcg64::seeded(0x50F7);
    for case in 0..40 {
        let cols = 2 + rng.below(9) as usize;
        let rows = 1 + rng.below(4) as usize;
        let mut vals = vec![0.0f32; rows * cols];
        Distribution::gauss_outliers().fill_f32(&mut rng, &mut vals);
        let mut sm = vals.clone();
        softmax_rows_f32(&mut sm, cols);
        for (r, row) in sm.chunks(cols).enumerate() {
            let sum: f64 = row.iter().map(|&v| v as f64).sum();
            assert!(
                (sum - 1.0).abs() < 1e-5,
                "case {case} row {r}: sum {sum}"
            );
            assert!(row.iter().all(|&p| p >= 0.0), "case {case} row {r}");
        }
        let rot = 1 + rng.below(cols as u64 - 1) as usize;
        let mut rotated = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for i in 0..cols {
                rotated[r * cols + (i + rot) % cols] = vals[r * cols + i];
            }
        }
        softmax_rows_f32(&mut rotated, cols);
        for r in 0..rows {
            for i in 0..cols {
                let a = sm[r * cols + i] as f64;
                let b = rotated[r * cols + (i + rot) % cols] as f64;
                assert!(
                    (a - b).abs() < 5e-6,
                    "case {case} row {r} col {i}: {a} vs {b} (rot {rot})"
                );
            }
        }
    }
}

#[test]
fn prop_one_by_one_conv_model_equals_the_flattened_gemm_model_bitwise() {
    use grcim::coordinator::CampaignConfig;
    use grcim::model::{parse_model, run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    // a 1x1 kernel makes im2col the identity reshape (HWC row-major ==
    // [H*W][Cin]), the image draw count equals the flattened GEMM's
    // input draw count, and the requantization visits elements in the
    // same order — so the whole chained report must agree bit for bit
    let cfg = TileConfig {
        nr: 4,
        nc: 4,
        fmts: FormatPair::new(FpFormat::fp(2, 2), FpFormat::fp4_e2m1()),
        arch: CimArch::GrUnit,
        adc: AdcPolicy::PerTileSpec,
        tech: TechParams::default(),
    };
    let mk = |model: &str| ModelSpec {
        name: "p".into(),
        layers: parse_model(model, 9).unwrap(),
        cfg,
        dist_x: Distribution::gauss_outliers(),
        dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
        relu: true,
        fit_activations: false,
    };
    let campaign = CampaignConfig {
        engine: EngineKind::Rust,
        workers: 2,
        seed: 5,
        ..Default::default()
    };
    let a = run_model(&mk("conv:4x3x1x1@3x3,gemm:9x4x2"), &campaign).unwrap();
    let b = run_model(&mk("gemm:9x3x4,gemm:9x4x2"), &campaign).unwrap();
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.y), bits(&b.y));
    assert_eq!(a.report.total_fj().to_bits(), b.report.total_fj().to_bits());
    assert_eq!(a.report.sqnr_db.to_bits(), b.report.sqnr_db.to_bits());
    for (la, lb) in a.report.layers.iter().zip(&b.report.layers) {
        assert_eq!(
            la.requant_sqnr_db.to_bits(),
            lb.requant_sqnr_db.to_bits()
        );
        let enobs = |l: &grcim::model::LayerOutcome| {
            l.report.tiles.iter().map(|t| t.enob.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(enobs(la), enobs(lb));
    }
}

#[test]
fn prop_attention_and_conv_models_bit_identical_across_1_2_4_workers() {
    use grcim::coordinator::CampaignConfig;
    use grcim::model::{parse_model, run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    // worker-count invariance must survive the new stage kinds: the
    // attention sub-GEMMs shard through the same pooled tile path, and
    // conv only changes operand staging
    for model in [
        "transformer:16x2x1",
        "decode:16x2x12",
        "conv:4x2x2x2@5x5,gemm:16x4x3",
    ] {
        let spec = ModelSpec {
            name: "det".into(),
            layers: parse_model(model, 2).unwrap(),
            cfg: TileConfig {
                nr: 8,
                nc: 4,
                fmts: FormatPair::new(FpFormat::fp(2, 2), FpFormat::fp4_e2m1()),
                arch: CimArch::GrUnit,
                adc: AdcPolicy::PerTileSpec,
                tech: TechParams::default(),
            },
            dist_x: Distribution::gauss_outliers(),
            dist_w: Distribution::max_entropy(FpFormat::fp4_e2m1()),
            relu: false,
            fit_activations: false,
        };
        let mut reference: Option<(Vec<u64>, Vec<u64>, u64, u64)> = None;
        for workers in [1usize, 2, 4] {
            let cfg = CampaignConfig {
                engine: EngineKind::Rust,
                workers,
                seed: 0xA77,
                ..Default::default()
            };
            let res = run_model(&spec, &cfg).unwrap();
            let y_bits: Vec<u64> = res.y.iter().map(|v| v.to_bits()).collect();
            let layer_bits: Vec<u64> = res
                .report
                .layers
                .iter()
                .flat_map(|l| {
                    let mut bits: Vec<u64> =
                        l.report.tiles.iter().map(|t| t.enob.to_bits()).collect();
                    bits.push(l.report.total_fj().to_bits());
                    bits.push(l.requant_sqnr_db.to_bits());
                    bits.push(
                        l.softmax_requant_db.unwrap_or(f64::NAN).to_bits(),
                    );
                    bits
                })
                .collect();
            let bits = (
                y_bits,
                layer_bits,
                res.report.sqnr_db.to_bits(),
                res.report.total_fj().to_bits(),
            );
            match &reference {
                None => reference = Some(bits),
                Some(r) => assert_eq!(
                    r, &bits,
                    "{model}: workers={workers} changed the model"
                ),
            }
        }
    }
}

#[test]
fn prop_transparent_adc_attention_chain_tracks_the_float_reference() {
    use grcim::coordinator::CampaignConfig;
    use grcim::model::{parse_model, run_model, ModelSpec};
    use grcim::runtime::EngineKind;
    use grcim::tile::{AdcPolicy, TileConfig};

    // with fine FP(4,10) operand formats on BOTH sides (K and V are
    // weight-stationary, so the attention stage re-encodes activation
    // slices in the array's *weight* format — at FP4 that quantization
    // dominates by design) and fixed 30-bit ADCs, the qkv -> attention
    // prefix must track the f64 reference chain (the Python twin pins
    // the identical case in its attn self-check, seed 13)
    let fine = FpFormat::fp(4, 10);
    let cfg = TileConfig {
        nr: 8,
        nc: 8,
        fmts: FormatPair::new(fine, fine),
        arch: CimArch::GrUnit,
        adc: AdcPolicy::Fixed(30.0),
        tech: TechParams::default(),
    };
    let campaign = CampaignConfig {
        engine: EngineKind::Rust,
        workers: 2,
        seed: 13,
        ..Default::default()
    };
    let mut layers = parse_model("transformer:8x2x1", 3).unwrap();
    layers.truncate(2); // qkv -> attn, the twin-verified prefix
    let spec = ModelSpec {
        name: "transparent".into(),
        layers,
        cfg,
        dist_x: Distribution::max_entropy(fine),
        dist_w: Distribution::max_entropy(fine),
        relu: false,
        fit_activations: false,
    };
    let res = run_model(&spec, &campaign).unwrap();
    assert!(
        res.report.sqnr_db > 25.0,
        "e2e sqnr {} dB under a transparent ADC",
        res.report.sqnr_db
    );
    let attn = &res.report.layers[1];
    assert!(
        attn.softmax_requant_db.unwrap() > 25.0,
        "softmax requant {:?}",
        attn.softmax_requant_db
    );
    // the same transparency holds for the decode GEMV over its KV cache
    let spec_dec = ModelSpec {
        name: "transparent-dec".into(),
        layers: parse_model("decode:8x2x6", 1).unwrap(),
        cfg,
        dist_x: Distribution::max_entropy(fine),
        dist_w: Distribution::max_entropy(fine),
        relu: false,
        fit_activations: false,
    };
    let res = run_model(&spec_dec, &campaign).unwrap();
    assert_eq!(res.y.len(), 8);
    let fj_tok = res.report.fj_per_token();
    assert!(fj_tok.is_finite() && fj_tok > 0.0);
    // one token: per-token energy is the whole model's energy
    assert_eq!(fj_tok.to_bits(), res.report.total_fj().to_bits());
}

#[test]
fn prop_tiled_outputs_independent_of_column_grouping() {
    use grcim::rng::Pcg64;
    use grcim::runtime::RustEngine;
    use grcim::tile::{gemm_with_engine, AdcPolicy, GemmShape, TileConfig};

    // column MACs are independent, so N_C only regroups energy
    // amortization — the digitized outputs must not move by a bit
    let shape = GemmShape { m: 2, k: 24, n: 10 };
    let mut rng = Pcg64::seeded(0x9C);
    let mut x = vec![0.0f32; shape.m * shape.k];
    Distribution::clipped_gauss4().fill_f32(&mut rng, &mut x);
    let mut wt = vec![0.0f32; shape.n * shape.k];
    Distribution::clipped_gauss4().fill_f32(&mut rng, &mut wt);
    let mut reference: Option<Vec<u64>> = None;
    for nc in [1usize, 3, 5, 10, 16] {
        let cfg = TileConfig {
            nr: 8,
            nc,
            fmts: FormatPair::new(FpFormat::fp(3, 2), FpFormat::fp4_e2m1()),
            arch: CimArch::GrUnit,
            adc: AdcPolicy::Fixed(7.0),
            tech: TechParams::default(),
        };
        let res = gemm_with_engine(&RustEngine, "nc", &cfg, shape, &x, &wt).unwrap();
        let bits: Vec<u64> = res.y.iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(r, &bits, "nc={nc} moved the outputs"),
        }
    }
}

#[test]
fn prop_explore_resume_after_kill_is_bit_identical() {
    use grcim::coordinator::CampaignConfig;
    use grcim::explore::{checkpoint, run_plan, ParetoPlan};
    use grcim::runtime::EngineKind;
    use std::collections::BTreeMap;

    // a killed explore campaign, resumed from its checkpoint, must emit
    // byte-for-byte the same final JSONL as an uninterrupted run — for
    // any worker count and any set of points finished before the kill
    let plan = ParetoPlan::from_toml(
        "name = \"resume-prop\"\nseed = 11\ntokens = 2\n\n[axes]\n\
         workload = \"gemm:2x8x4\"\nnr = [4, 8]\nnc = 4\n\
         arch = [\"gr-unit\", \"conventional\"]\nn_e = 2\nn_m = 2\n",
    )
    .unwrap();
    let total = plan.num_points();
    assert_eq!(total, 4);
    let campaign = |workers: usize| CampaignConfig {
        engine: EngineKind::Rust,
        workers,
        seed: 11,
        ..Default::default()
    };
    let full = run_plan(&plan, &campaign(1), None, BTreeMap::new()).unwrap();
    let want = full.out_jsonl("rust");

    let dir = std::env::temp_dir().join("grcim_resume_prop");
    std::fs::create_dir_all(&dir).unwrap();
    // kill scenarios: nothing finished, a prefix, an out-of-order
    // subset (workers complete points in any order), all but one
    let survivors: [&[usize]; 4] = [&[], &[0], &[2, 0], &[3, 1, 0]];
    for (si, keep) in survivors.iter().enumerate() {
        for workers in [1usize, 2, 4] {
            let path = dir.join(format!("kill{si}_w{workers}.jsonl"));
            let _ = std::fs::remove_file(&path);
            // simulate the killed run: header + the finished points
            let ck = checkpoint::create(&path, &plan, "rust").unwrap();
            for &i in keep.iter() {
                ck.writer.append(&full.points[i]).unwrap();
            }
            drop(ck);
            let ck = checkpoint::resume(&path, Some(&plan)).unwrap();
            assert_eq!(ck.done.len(), keep.len(), "scenario {si}");
            let resumed =
                run_plan(&ck.plan, &campaign(workers), Some(ck.writer), ck.done).unwrap();
            assert_eq!(
                resumed.out_jsonl("rust"),
                want,
                "scenario {si} at {workers} workers diverged"
            );
            // the checkpoint file now holds every point exactly once
            let done = checkpoint::resume(&path, Some(&plan)).unwrap().done;
            assert_eq!(done.len(), total, "scenario {si}");
            let _ = std::fs::remove_file(&path);
        }
    }
}
