//! Serve-layer integration: a real TCP server hammered by concurrent
//! clients, asserting the ISSUE-2 acceptance criteria directly —
//!
//! * with 8 concurrent clients issuing a mix of 4 distinct specs, the
//!   server computes each spec exactly once (single-flight `computes`
//!   counter),
//! * cache-hit responses are bit-identical to the cold computes, and
//! * shutdown is clean (acceptor + connection handlers joined; the
//!   listener port actually closes).

use grcim::config::Json;
use grcim::coordinator::CampaignConfig;
use grcim::runtime::EngineKind;
use grcim::server::{query_once, ServeConfig, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

fn spawn_server() -> Server {
    Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        campaign: CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 7,
            ..Default::default()
        },
        cache_entries: 256,
    })
    .expect("server spawns on an ephemeral port")
}

/// The payload of a successful response, rendered back to a canonical
/// string (numbers in shortest round-trip form: equal strings <=> equal
/// bit patterns).
fn result_str(line: &str) -> String {
    let j = Json::parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
    j.get("result").expect("ok responses carry a result").to_string()
}

fn cached_flag(line: &str) -> bool {
    Json::parse(line).unwrap().get("cached") == Some(&Json::Bool(true))
}

/// Four distinct spec points (distinct DR ⇒ distinct INT and FP
/// experiments ⇒ 8 distinct aggregate cache keys).
fn distinct_requests() -> Vec<String> {
    [(30.1, 22.83), (36.12, 22.83), (42.14, 28.85), (48.16, 28.85)]
        .iter()
        .map(|(dr, sqnr)| {
            format!(
                r#"{{"cmd":"energy","dr":{dr},"sqnr":{sqnr},"samples":512}}"#
            )
        })
        .collect()
}

#[test]
fn concurrent_clients_single_flight_and_bit_identical_hits() {
    const CLIENTS: usize = 8;
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let reqs = distinct_requests();

    // 8 clients, 2 per spec, released together; each client sends its
    // request twice (the second is a guaranteed cache hit — its own
    // first response completed).
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let req = reqs[i % 4].clone();
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let first = query_once(&addr, &req).unwrap();
                let second = query_once(&addr, &req).unwrap();
                (i % 4, first, second)
            })
        })
        .collect();

    let mut per_spec: Vec<Vec<String>> = vec![Vec::new(); 4];
    for h in handles {
        let (spec_idx, first, second) = h.join().expect("client panicked");
        assert!(
            cached_flag(&second),
            "second identical request must be served from cache"
        );
        per_spec[spec_idx].push(result_str(&first));
        per_spec[spec_idx].push(result_str(&second));
    }

    // bit-identical: every response for one spec — cold, coalesced, or
    // cached — carries the exact same payload
    for (i, results) in per_spec.iter().enumerate() {
        assert_eq!(results.len(), 4, "2 clients x 2 requests per spec");
        for r in &results[1..] {
            assert_eq!(r, &results[0], "spec {i} responses diverged");
        }
    }

    // a later cold-start-free client sees the same bytes again
    for (i, req) in reqs.iter().enumerate() {
        let resp = query_once(&addr, req).unwrap();
        assert!(cached_flag(&resp), "spec {i} must be resident");
        assert_eq!(result_str(&resp), per_spec[i][0]);
    }

    // single-flight: 4 specs x 2 aggregates (INT + FP) = exactly 8
    // computations despite 24 requests
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    let j = Json::parse(&info).unwrap();
    let aggs = j.get("result").unwrap().get("aggregates").unwrap();
    assert_eq!(
        aggs.get("computes").unwrap().as_usize(),
        Some(8),
        "single-flight violated: {info}"
    );
    assert_eq!(aggs.get("entries").unwrap().as_usize(), Some(8));
    let hits = aggs.get("hits").unwrap().as_usize().unwrap();
    let coalesced = aggs.get("coalesced").unwrap().as_usize().unwrap();
    // 20 energy requests -> 40 aggregate lookups, 8 computed, the rest
    // either hit the cache or coalesced onto a leader
    assert_eq!(hits + coalesced, 40 - 8, "{info}");

    // clean shutdown: all handles joined inside, port actually closed
    server.shutdown().expect("clean shutdown");
    assert!(
        TcpStream::connect(&addr).is_err(),
        "listener must be closed after shutdown"
    );
}

#[test]
fn mixed_request_kinds_share_one_connection() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();

    // one persistent connection, several request kinds back-to-back
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut send = |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    };

    let sweep = send(
        r#"{"cmd":"sweep","samples":512,"experiments":[
            {"name":"a","n_e":3,"n_m":2,"nr":32,"distribution":"uniform"}]}"#,
    );
    let rows = Json::parse(&sweep)
        .unwrap()
        .get("result")
        .unwrap()
        .get("experiments")
        .unwrap()
        .items()
        .len();
    assert_eq!(rows, 1);

    // malformed line -> error response, connection survives
    let err = send("garbage");
    assert_eq!(Json::parse(&err).unwrap().get("ok"), Some(&Json::Bool(false)));

    let fig = send(r#"{"cmd":"figure","id":"table1","samples":256}"#);
    let fig_cached = send(r#"{"cmd":"figure","id":"table1","samples":256}"#);
    assert_eq!(result_str(&fig), result_str(&fig_cached));
    assert!(cached_flag(&fig_cached));

    let info = send(r#"{"cmd":"info"}"#);
    assert_eq!(Json::parse(&info).unwrap().get("ok"), Some(&Json::Bool(true)));

    drop(writer);
    drop(reader);
    server.shutdown().unwrap();
}

#[test]
fn oversized_line_resyncs_the_reader_instead_of_parsing_garbage() {
    // the 1 MiB line cap truncates a request mid-line; the reader must
    // (a) answer with exactly one error, (b) discard the rest of that
    // line without parsing it as a request, and (c) keep serving the
    // same connection normally afterwards
    const MAX_LINE: usize = 1 << 20; // server::MAX_LINE
    let server = spawn_server();
    let addr = server.local_addr().to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // an oversized "request": valid-JSON-looking prefix, then filler
    // well past the cap, then a newline — the tail after the cap would
    // parse as garbage if the reader failed to resync
    let mut big = String::with_capacity(MAX_LINE + 64);
    big.push_str(r#"{"cmd":"energy","dr":"#);
    while big.len() <= MAX_LINE {
        big.push('9');
    }
    big.push_str("}\n");
    writer.write_all(big.as_bytes()).unwrap();
    writer.flush().unwrap();

    // exactly one response for the oversized line: the cap error
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let j = Json::parse(resp.trim_end()).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{resp}");
    assert!(
        j.get("error").and_then(Json::as_str).unwrap().contains("exceeds"),
        "{resp}"
    );

    // the connection is still usable: the next complete line is a
    // normal request and gets a normal response — if the reader had
    // parsed the discarded tail, an extra "not valid JSON" error line
    // would arrive here instead of the info result
    writer.write_all(b"{\"cmd\":\"info\"}\n").unwrap();
    writer.flush().unwrap();
    let mut info = String::new();
    reader.read_line(&mut info).unwrap();
    let j = Json::parse(info.trim_end()).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{info}");
    assert!(j.get("result").unwrap().get("engine").is_some(), "{info}");

    drop(writer);
    drop(reader);
    server.shutdown().unwrap();
}

#[test]
fn final_request_without_trailing_newline_is_still_answered() {
    // `printf '{"cmd":"info"}' | nc` style clients terminate the last
    // request with EOF instead of a newline; the reader must answer it
    // rather than silently closing the connection
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(b"{\"cmd\":\"info\"}").unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let j = Json::parse(resp.trim_end()).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
    drop(reader);
    server.shutdown().unwrap();
}

#[test]
fn model_requests_coalesce_over_tcp_and_hits_are_identical() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let req = r#"{"cmd":"model","model":"mlp:16x12x8","tokens":2,"nr":8,"nc":4,"n_e":2}"#;

    const CLIENTS: usize = 4;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                query_once(&addr, req).unwrap()
            })
        })
        .collect();
    let responses: Vec<String> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let first = result_str(&responses[0]);
    for r in &responses {
        assert_eq!(result_str(r), first, "model responses diverged");
    }

    // exactly one model compute despite 4 concurrent clients
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    let models = Json::parse(&info)
        .unwrap()
        .get("result")
        .unwrap()
        .get("models")
        .unwrap()
        .clone();
    assert_eq!(models.get("computes").unwrap().as_usize(), Some(1), "{info}");
    server.shutdown().unwrap();
}

#[test]
fn shutdown_is_clean_with_an_idle_connection_open() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    // a client that connects and then goes silent
    let idle = TcpStream::connect(&addr).unwrap();
    // the handler notices the shutdown flag on its next idle tick; this
    // must not hang even though the client never closed
    server.shutdown().expect("shutdown with idle connection");
    drop(idle);
    assert!(TcpStream::connect(&addr).is_err());
}

#[test]
fn distinct_seeds_are_distinct_cache_entries() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let a = query_once(
        &addr,
        r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512,"seed":1}"#,
    )
    .unwrap();
    let b = query_once(
        &addr,
        r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512,"seed":2}"#,
    )
    .unwrap();
    assert_ne!(
        result_str(&a),
        result_str(&b),
        "different seeds must not alias in the cache"
    );
    server.shutdown().unwrap();
}
