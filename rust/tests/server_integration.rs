//! Serve-layer integration: a real TCP server hammered by concurrent
//! clients, asserting the serve-core acceptance criteria directly —
//!
//! * ~1000 concurrent loadgen connections are served on a **bounded
//!   thread count** (the event loop holds connections as state, not
//!   threads), with byte-identical cached responses and a `metrics`
//!   response carrying nonzero hit/compute counters and latency
//!   percentiles,
//! * concurrent identical requests single-flight to one computation
//!   (`computes` counters via `info`),
//! * admission control rejects overload with typed `busy` errors,
//! * slow-loris and oversized-line clients cannot wedge the server, and
//! * shutdown is clean (every thread joined; the listener port closes).

use grcim::config::Json;
use grcim::coordinator::CampaignConfig;
use grcim::runtime::EngineKind;
use grcim::server::loadgen::{self, LoadgenConfig};
use grcim::server::{query_once, ServeConfig, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

fn spawn_server() -> Server {
    Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        campaign: CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 7,
            ..Default::default()
        },
        cache_entries: 256,
        ..Default::default()
    })
    .expect("server spawns on an ephemeral port")
}

/// The payload of a successful response, rendered back to a canonical
/// string (numbers in shortest round-trip form: equal strings <=> equal
/// bit patterns).
fn result_str(line: &str) -> String {
    let j = Json::parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
    j.get("result").expect("ok responses carry a result").to_string()
}

fn cached_flag(line: &str) -> bool {
    Json::parse(line).unwrap().get("cached") == Some(&Json::Bool(true))
}

/// Four distinct spec points (distinct DR ⇒ distinct INT and FP
/// experiments ⇒ 8 distinct aggregate cache keys).
fn distinct_requests() -> Vec<String> {
    [(30.1, 22.83), (36.12, 22.83), (42.14, 28.85), (48.16, 28.85)]
        .iter()
        .map(|(dr, sqnr)| format!(r#"{{"cmd":"energy","dr":{dr},"sqnr":{sqnr},"samples":512}}"#))
        .collect()
}

#[test]
fn concurrent_clients_single_flight_and_bit_identical_hits() {
    const CLIENTS: usize = 8;
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let reqs = distinct_requests();

    // 8 clients, 2 per spec, released together; each client sends its
    // request twice (the second is a guaranteed cache hit — its own
    // first response completed).
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let req = reqs[i % 4].clone();
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let first = query_once(&addr, &req).unwrap();
                let second = query_once(&addr, &req).unwrap();
                (i % 4, first, second)
            })
        })
        .collect();

    let mut per_spec: Vec<Vec<String>> = vec![Vec::new(); 4];
    for h in handles {
        let (spec_idx, first, second) = h.join().expect("client panicked");
        assert!(
            cached_flag(&second),
            "second identical request must be served from cache"
        );
        per_spec[spec_idx].push(result_str(&first));
        per_spec[spec_idx].push(result_str(&second));
    }

    // bit-identical: every response for one spec — cold, coalesced, or
    // cached — carries the exact same payload
    for (i, results) in per_spec.iter().enumerate() {
        assert_eq!(results.len(), 4, "2 clients x 2 requests per spec");
        for r in &results[1..] {
            assert_eq!(r, &results[0], "spec {i} responses diverged");
        }
    }

    // a later cold-start-free client sees the same bytes again
    for (i, req) in reqs.iter().enumerate() {
        let resp = query_once(&addr, req).unwrap();
        assert!(cached_flag(&resp), "spec {i} must be resident");
        assert_eq!(result_str(&resp), per_spec[i][0]);
    }

    // single-flight at both cache levels, read through `info`:
    // 20 energy requests (8 clients x 2 + 4 verification) over 4 specs
    // hit the rendered-response cache (4 computes), and only those 4
    // cold renders ever touched the aggregate cache (4 specs x 2
    // aggregates = 8 computes)
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    let j = Json::parse(&info).unwrap();
    let aggs = j.get("result").unwrap().get("aggregates").unwrap();
    assert_eq!(
        aggs.get("computes").unwrap().as_usize(),
        Some(8),
        "single-flight violated: {info}"
    );
    assert_eq!(aggs.get("entries").unwrap().as_usize(), Some(8));
    let energies = j.get("result").unwrap().get("energies").unwrap();
    assert_eq!(energies.get("computes").unwrap().as_usize(), Some(4), "{info}");
    let hits = energies.get("hits").unwrap().as_usize().unwrap();
    let coalesced = energies.get("coalesced").unwrap().as_usize().unwrap();
    // 20 energy requests -> 4 computed, the rest either hit the
    // rendered cache or coalesced onto a leader
    assert_eq!(hits + coalesced, 20 - 4, "{info}");

    // clean shutdown: all threads joined inside, port actually closed
    server.shutdown().expect("clean shutdown");
    assert!(
        TcpStream::connect(&addr).is_err(),
        "listener must be closed after shutdown"
    );
}

/// The soft open-files limit caps how many concurrent connections one
/// test process can hold (each costs 2 fds: client + server end live in
/// this process). CI raises the limit to 8192 and gets the full 1000;
/// a dev box at the default 1024 still runs the test at reduced width.
fn max_conns_for_fd_limit(want: usize) -> usize {
    let soft = std::fs::read_to_string("/proc/self/limits")
        .ok()
        .and_then(|text| {
            let line = text.lines().find(|l| l.starts_with("Max open files"))?;
            line.split_whitespace().nth(3)?.parse::<usize>().ok()
        })
        .unwrap_or(1024);
    let cap = (soft.saturating_sub(224) / 2).max(64);
    want.min(cap)
}

/// Count this process's live threads (Linux; `None` elsewhere).
fn thread_count() -> Option<usize> {
    if cfg!(target_os = "linux") {
        Some(std::fs::read_dir("/proc/self/task").ok()?.count())
    } else {
        None
    }
}

#[test]
fn thousand_connections_on_a_bounded_thread_count() {
    // the core acceptance test for the event-loop serve core: ~1000
    // concurrent connections, mixed request kinds, byte-identical cached
    // responses, and thread count bounded by the fixed pools — not by
    // the connection count
    let server = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        campaign: CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 7,
            ..Default::default()
        },
        cache_entries: 256,
        mux_threads: 2,
        compute_threads: 2,
        queue_cap: 4096,
    })
    .expect("server spawns");
    let addr = server.local_addr().to_string();

    // warm the two energy specs so the flood is dominated by cache hits
    // (the byte-identity reference is the cold compute)
    let warm_a = r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512}"#;
    let warm_b = r#"{"cmd":"energy","dr":36.12,"sqnr":22.83,"samples":512}"#;
    let cold_a = result_str(&query_once(&addr, warm_a).unwrap());
    assert!(cached_flag(&query_once(&addr, warm_a).unwrap()));
    result_str(&query_once(&addr, warm_b).unwrap());

    // sample the process's thread count throughout the flood
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut max = 0usize;
            while !stop.load(Ordering::Relaxed) {
                if let Some(n) = thread_count() {
                    max = max.max(n);
                }
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            max
        })
    };

    let conns = max_conns_for_fd_limit(1000);
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        conns,
        per_conn: 2,
        lines: vec![
            warm_a.to_string(),
            warm_b.to_string(),
            r#"{"cmd":"info"}"#.to_string(),
            r#"{"cmd":"metrics"}"#.to_string(),
        ],
        threads: 8,
        loris_ms: 0,
    })
    .expect("loadgen runs");
    stop.store(true, Ordering::Relaxed);
    let max_threads = sampler.join().unwrap();

    assert_eq!(report.connected as usize, conns, "{report:?}");
    assert_eq!(report.connect_errors, 0, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    assert_eq!(report.divergent, 0, "cached responses diverged: {report:?}");
    assert_eq!(report.sent, (conns * 2) as u64);
    assert_eq!(report.ok, report.sent, "{report:?}");

    // bounded threads: acceptor + 2 muxes + 2 compute workers + campaign
    // workers + 8 loadgen drivers + whatever the concurrently-running
    // sibling tests spawn — far below one thread per connection (the
    // old thread-per-connection design would sit at ~conns+10 here)
    if thread_count().is_some() {
        assert!(
            max_threads < 250,
            "thread count scaled with connections: {max_threads} threads \
             for {conns} connections"
        );
        assert!(max_threads >= 13, "sampler missed the flood: {max_threads}");
    }

    // the metrics request reports the flood: nonzero hit/compute
    // counters and real latency percentiles per kind
    let m = query_once(&addr, r#"{"cmd":"metrics"}"#).unwrap();
    let j = Json::parse(&m).unwrap();
    let r = j.get("result").unwrap();
    let server_block = r.get("server").unwrap();
    assert!(
        server_block.get("accepted").unwrap().as_usize().unwrap() >= conns,
        "{m}"
    );
    assert_eq!(server_block.get("bad_requests").unwrap().as_usize(), Some(0));
    let energy = server_block.get("kinds").unwrap().get("energy").unwrap();
    assert!(energy.get("ok").unwrap().as_usize().unwrap() >= conns / 2, "{m}");
    assert!(energy.get("p50_us").unwrap().as_f64().unwrap() > 0.0, "{m}");
    assert!(
        energy.get("p99_us").unwrap().as_f64().unwrap()
            >= energy.get("p50_us").unwrap().as_f64().unwrap(),
        "{m}"
    );
    let caches = r.get("caches").unwrap();
    let energies = caches.get("energies").unwrap();
    assert_eq!(energies.get("computes").unwrap().as_usize(), Some(2), "{m}");
    assert!(energies.get("hits").unwrap().as_usize().unwrap() >= conns, "{m}");
    assert_eq!(
        caches.get("aggregates").unwrap().get("computes").unwrap().as_usize(),
        Some(4),
        "four aggregates (2 specs x INT+FP), never recomputed: {m}"
    );

    // every response delivered, every thread joined, port closed
    server.shutdown().expect("clean shutdown after the flood");
    assert!(TcpStream::connect(&addr).is_err());

    // the warm spec's bytes never changed across the whole flood
    assert!(!cold_a.is_empty());
}

#[test]
fn overload_gets_typed_busy_errors_not_queue_collapse() {
    // 1 compute worker + queue capacity 1: a volley of distinct cold
    // requests must see typed `busy` rejections, not unbounded queueing
    let server = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        campaign: CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 7,
            ..Default::default()
        },
        cache_entries: 256,
        mux_threads: 1,
        compute_threads: 1,
        queue_cap: 1,
    })
    .expect("server spawns");
    let addr = server.local_addr().to_string();

    const CLIENTS: usize = 12;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            // distinct DR values: every request is a distinct cold
            // compute of a few hundred ms — the queue must overflow
            let req = format!(
                r#"{{"cmd":"energy","dr":{},"sqnr":22.83,"samples":16384}}"#,
                30.1 + i as f64 * 0.37
            );
            std::thread::spawn(move || {
                barrier.wait();
                query_once(&addr, &req).unwrap()
            })
        })
        .collect();

    let mut ok = 0usize;
    let mut busy = 0usize;
    for h in handles {
        let resp = h.join().unwrap();
        let j = Json::parse(&resp).unwrap();
        if j.get("ok") == Some(&Json::Bool(true)) {
            ok += 1;
        } else {
            assert_eq!(
                j.get("kind").and_then(Json::as_str),
                Some("busy"),
                "only typed busy rejections expected: {resp}"
            );
            busy += 1;
        }
    }
    assert!(ok >= 1, "at least the first admitted request completes");
    assert!(busy >= 1, "a 12-deep volley into a 1-slot queue must reject");
    assert_eq!(ok + busy, CLIENTS);

    let m = query_once(&addr, r#"{"cmd":"metrics"}"#).unwrap();
    let server_block =
        Json::parse(&m).unwrap().get("result").unwrap().get("server").unwrap().clone();
    assert_eq!(
        server_block.get("rejected_busy").unwrap().as_usize(),
        Some(busy),
        "{m}"
    );
    server.shutdown().unwrap();
}

#[test]
fn slow_loris_writers_do_not_starve_other_connections() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    // warm one spec so loadgen responses are cache hits
    let warm = r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512}"#;
    result_str(&query_once(&addr, warm).unwrap());

    // many connections all mid-line at once: write half a request, stall
    // 30 ms, finish it — the event loop must keep every other connection
    // flowing while the halves sit in the accumulators
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        conns: 100,
        per_conn: 2,
        lines: vec![warm.to_string()],
        threads: 4,
        loris_ms: 30,
    })
    .expect("loadgen runs");
    assert_eq!(report.connect_errors, 0, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    assert_eq!(report.divergent, 0, "{report:?}");
    assert_eq!(report.ok, report.sent, "{report:?}");

    // a fresh client still gets an immediate answer while stalled
    // writers exist
    let holdout = TcpStream::connect(&addr).unwrap();
    let mut half = holdout.try_clone().unwrap();
    half.write_all(br#"{"cmd":"ener"#).unwrap(); // never completed
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    assert!(Json::parse(&info).unwrap().get("ok") == Some(&Json::Bool(true)));
    drop(half);
    drop(holdout);
    server.shutdown().unwrap();
}

#[test]
fn mixed_request_kinds_share_one_connection() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();

    // one persistent connection, several request kinds back-to-back
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut send = |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    };

    // requests are newline-delimited: the sweep spec must be one line
    let mut sweep_req = String::from(r#"{"cmd":"sweep","samples":512,"experiments":"#);
    sweep_req.push_str(r#"[{"name":"a","n_e":3,"n_m":2,"nr":32,"distribution":"uniform"}]}"#);
    let sweep = send(&sweep_req);
    let rows = Json::parse(&sweep)
        .unwrap()
        .get("result")
        .unwrap()
        .get("experiments")
        .unwrap()
        .items()
        .len();
    assert_eq!(rows, 1);

    // malformed line -> typed bad_request, connection survives
    let err = send("garbage");
    let ej = Json::parse(&err).unwrap();
    assert_eq!(ej.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(ej.get("kind").and_then(Json::as_str), Some("bad_request"));

    let fig = send(r#"{"cmd":"figure","id":"table1","samples":256}"#);
    let fig_cached = send(r#"{"cmd":"figure","id":"table1","samples":256}"#);
    assert_eq!(result_str(&fig), result_str(&fig_cached));
    assert!(cached_flag(&fig_cached));

    let info = send(r#"{"cmd":"info"}"#);
    assert_eq!(Json::parse(&info).unwrap().get("ok"), Some(&Json::Bool(true)));

    // a metrics request on the same connection sees its own traffic
    let m = send(r#"{"cmd":"metrics"}"#);
    let kinds = Json::parse(&m)
        .unwrap()
        .get("result")
        .unwrap()
        .get("server")
        .unwrap()
        .get("kinds")
        .unwrap()
        .clone();
    assert!(kinds.get("sweep").unwrap().get("ok").unwrap().as_usize().unwrap() >= 1);
    assert!(kinds.get("figure").unwrap().get("ok").unwrap().as_usize().unwrap() >= 2);

    drop(writer);
    drop(reader);
    server.shutdown().unwrap();
}

#[test]
fn oversized_line_resyncs_the_reader_instead_of_parsing_garbage() {
    // the 1 MiB line cap truncates a request mid-line; the reader must
    // (a) answer with exactly one error, (b) discard the rest of that
    // line without parsing it as a request, and (c) keep serving the
    // same connection normally afterwards
    const MAX_LINE: usize = 1 << 20; // server::MAX_LINE
    let server = spawn_server();
    let addr = server.local_addr().to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // an oversized "request": valid-JSON-looking prefix, then filler
    // well past the cap, then a newline — the tail after the cap would
    // parse as garbage if the reader failed to resync
    let mut big = String::with_capacity(2 * MAX_LINE + 64);
    big.push_str(r#"{"cmd":"energy","dr":"#);
    while big.len() <= 2 * MAX_LINE {
        big.push('9');
    }
    big.push_str("}\n");
    writer.write_all(big.as_bytes()).unwrap();
    writer.flush().unwrap();

    // exactly one response for the oversized line: the cap error
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let j = Json::parse(resp.trim_end()).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{resp}");
    assert!(
        j.get("error").and_then(Json::as_str).unwrap().contains("exceeds"),
        "{resp}"
    );

    // the connection is still usable: the next complete line is a
    // normal request and gets a normal response — if the reader had
    // parsed the discarded tail, an extra "not valid JSON" error line
    // would arrive here instead of the info result
    writer.write_all(b"{\"cmd\":\"info\"}\n").unwrap();
    writer.flush().unwrap();
    let mut info = String::new();
    reader.read_line(&mut info).unwrap();
    let j = Json::parse(info.trim_end()).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{info}");
    assert!(j.get("result").unwrap().get("engine").is_some(), "{info}");

    drop(writer);
    drop(reader);
    server.shutdown().unwrap();
}

#[test]
fn final_request_without_trailing_newline_is_still_answered() {
    // `printf '{"cmd":"info"}' | nc` style clients terminate the last
    // request with EOF instead of a newline; the reader must answer it
    // rather than silently closing the connection
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(b"{\"cmd\":\"info\"}").unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let j = Json::parse(resp.trim_end()).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
    drop(reader);
    server.shutdown().unwrap();
}

#[test]
fn model_requests_coalesce_over_tcp_and_hits_are_identical() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let req = r#"{"cmd":"model","model":"mlp:16x12x8","tokens":2,"nr":8,"nc":4,"n_e":2}"#;

    const CLIENTS: usize = 4;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                query_once(&addr, req).unwrap()
            })
        })
        .collect();
    let responses: Vec<String> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let first = result_str(&responses[0]);
    for r in &responses {
        assert_eq!(result_str(r), first, "model responses diverged");
    }

    // exactly one model compute despite 4 concurrent clients
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    let models = Json::parse(&info)
        .unwrap()
        .get("result")
        .unwrap()
        .get("models")
        .unwrap()
        .clone();
    assert_eq!(models.get("computes").unwrap().as_usize(), Some(1), "{info}");
    server.shutdown().unwrap();
}

#[test]
fn transformer_and_decode_presets_hit_the_rendered_cache_byte_identically() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    for (req, layers) in [
        (
            r#"{"cmd":"model","model":"transformer:16x2x1","tokens":2,"nr":8,"nc":4,"n_e":2}"#,
            5usize,
        ),
        (
            r#"{"cmd":"model","model":"decode:16x2x12","tokens":1,"nr":8,"nc":4,"n_e":2}"#,
            3usize,
        ),
    ] {
        let cold = query_once(&addr, req).unwrap();
        assert!(!cached_flag(&cold), "first request must be computed: {cold}");
        let warm = query_once(&addr, req).unwrap();
        assert!(cached_flag(&warm), "second identical request must hit: {warm}");
        assert_eq!(result_str(&warm), result_str(&cold), "cache hit diverged");
        let j = Json::parse(&cold).unwrap();
        assert_eq!(
            j.get("result").unwrap().get("layers").unwrap().as_usize(),
            Some(layers),
            "{cold}"
        );
    }

    // the two presets are distinct cache entries, each computed once
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    let models = Json::parse(&info)
        .unwrap()
        .get("result")
        .unwrap()
        .get("models")
        .unwrap()
        .clone();
    assert_eq!(models.get("computes").unwrap().as_usize(), Some(2), "{info}");
    server.shutdown().unwrap();
}

#[test]
fn oversized_decode_request_trips_the_slab_cap_as_a_typed_bad_request() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    // ctx = 10^6: every dimension individually parses (< 2^20), and the
    // MAC total (2·M·S·d ≈ 2.0e9) stays under the MAC cap — but the KV
    // cache alone is 2·ctx·d ≈ 2.0e9 operand elements, far past
    // MAX_LAYER_ELEMS. The O(ctx²)-audited slab cap must reject it with
    // a typed bad_request before any worker tries to allocate it.
    let req =
        r#"{"cmd":"model","model":"decode:1024x4x1000000","tokens":1,"nr":8,"nc":4}"#;
    let resp = query_once(&addr, req).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{resp}");
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("bad_request"), "{resp}");
    assert!(
        j.get("error").and_then(Json::as_str).unwrap().contains("too large"),
        "{resp}"
    );

    // the rejection left the server healthy and the connection path clean
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    assert_eq!(Json::parse(&info).unwrap().get("ok"), Some(&Json::Bool(true)));
    server.shutdown().unwrap();
}

#[test]
fn shutdown_is_clean_with_an_idle_connection_open() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    // a client that connects and then goes silent
    let idle = TcpStream::connect(&addr).unwrap();
    // the mux flushes and closes it during the drain; this must not hang
    // even though the client never closed
    server.shutdown().expect("shutdown with idle connection");
    drop(idle);
    assert!(TcpStream::connect(&addr).is_err());
}

#[test]
fn mux_panic_surfaces_in_shutdown_error_and_stops_routing() {
    use std::time::{Duration, Instant};
    // fault injection: a request line containing the needle makes the
    // (only) mux thread panic mid-dispatch. The pinned behavior: the
    // panic is caught, the acceptor detects the dead mux and stops
    // routing, and the panic surfaces as the shutdown/join error — the
    // server must neither hang nor pretend the drain was clean.
    let server = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        campaign: CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 7,
            ..Default::default()
        },
        cache_entries: 256,
        mux_threads: 1,
        compute_threads: 1,
        queue_cap: 16,
        mux_panic_line: Some("detonate-mux".to_string()),
    })
    .expect("server spawns");
    let addr = server.local_addr().to_string();

    // a healthy request first: the hook must not affect normal traffic
    let info = query_once(&addr, r#"{"cmd":"info"}"#).unwrap();
    assert_eq!(Json::parse(&info).unwrap().get("ok"), Some(&Json::Bool(true)));

    // trigger: the mux panics while handling this line; its connections
    // drop during the unwind, so the client observes EOF/reset, never a
    // response
    let trigger = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(trigger.try_clone().unwrap());
    let mut writer = trigger;
    writer.write_all(b"{\"cmd\":\"detonate-mux\"}\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    match reader.read_line(&mut resp) {
        Ok(n) => assert_eq!(n, 0, "dead mux produced a response: {resp}"),
        Err(_) => {} // connection reset during the unwind is equally fine
    }

    // the acceptor stops routing: probes are never answered (a brief
    // window may still enqueue them onto the not-yet-marked-dead
    // mailbox — they time out), and once the dead mux is observed the
    // acceptor exits and the port closes
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match TcpStream::connect(&addr) {
            Err(_) => break, // listener closed: the acceptor stopped
            Ok(mut probe) => {
                probe
                    .set_read_timeout(Some(Duration::from_millis(100)))
                    .unwrap();
                let _ = probe.write_all(b"{\"cmd\":\"info\"}\n");
                let mut buf = [0u8; 64];
                let got = std::io::Read::read(&mut probe, &mut buf);
                assert!(
                    !matches!(got, Ok(n) if n > 0),
                    "a request was served after the only mux died"
                );
            }
        }
        assert!(
            Instant::now() < deadline,
            "acceptor never detected the dead mux"
        );
    }

    // the root cause is the drain error, not a silent Ok
    let err = format!("{:#}", server.shutdown().unwrap_err());
    assert!(err.contains("mux 0 panicked"), "{err}");
    assert!(err.contains("injected"), "{err}");
    assert!(TcpStream::connect(&addr).is_err(), "port must be closed");
}

#[test]
fn sibling_muxes_keep_serving_after_one_mux_panics() {
    use std::time::{Duration, Instant};
    // two muxes, one killed by fault injection: the acceptor must route
    // around the dead mux (new connections land on the survivor and are
    // served normally), and the panic still surfaces at shutdown
    let server = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        campaign: CampaignConfig {
            engine: EngineKind::Rust,
            workers: 2,
            seed: 7,
            ..Default::default()
        },
        cache_entries: 256,
        mux_threads: 2,
        compute_threads: 1,
        queue_cap: 16,
        mux_panic_line: Some("detonate-mux".to_string()),
    })
    .expect("server spawns");
    let addr = server.local_addr().to_string();

    // the very first connection round-robins onto mux 0; kill it
    let trigger = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(trigger.try_clone().unwrap());
    let mut writer = trigger;
    writer.write_all(b"{\"cmd\":\"detonate-mux\"}\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    match reader.read_line(&mut resp) {
        Ok(n) => assert_eq!(n, 0, "dead mux produced a response: {resp}"),
        Err(_) => {}
    }

    // new connections are still served: a probe may race the dead-mux
    // mark and time out, but the acceptor must converge onto mux 1
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut served = false;
    while !served {
        assert!(
            Instant::now() < deadline,
            "no request was served after a sibling mux died"
        );
        let Ok(probe) = TcpStream::connect(&addr) else {
            panic!("listener closed with a live mux remaining");
        };
        probe
            .set_read_timeout(Some(Duration::from_millis(200)))
            .unwrap();
        let mut pr = BufReader::new(probe.try_clone().unwrap());
        let mut pw = probe;
        if pw.write_all(b"{\"cmd\":\"info\"}\n").is_err() {
            continue;
        }
        let mut line = String::new();
        if pr.read_line(&mut line).is_ok() && !line.is_empty() {
            let j = Json::parse(line.trim_end()).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
            served = true;
        }
    }

    // once routing has converged, service is fully healthy — including
    // compute requests through the admission queue
    let e = query_once(&addr, r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512}"#)
        .unwrap();
    assert_eq!(Json::parse(&e).unwrap().get("ok"), Some(&Json::Bool(true)), "{e}");

    // the drain still reports the mux 0 panic as its root cause
    let err = format!("{:#}", server.shutdown().unwrap_err());
    assert!(err.contains("mux 0 panicked"), "{err}");
    assert!(TcpStream::connect(&addr).is_err());
}

#[test]
fn distinct_seeds_are_distinct_cache_entries() {
    let server = spawn_server();
    let addr = server.local_addr().to_string();
    let a = query_once(
        &addr,
        r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512,"seed":1}"#,
    )
    .unwrap();
    let b = query_once(
        &addr,
        r#"{"cmd":"energy","dr":30.1,"sqnr":22.83,"samples":512,"seed":2}"#,
    )
    .unwrap();
    assert_ne!(
        result_str(&a),
        result_str(&b),
        "different seeds must not alias in the cache"
    );
    server.shutdown().unwrap();
}
