//! Mini design-space exploration: a coarse Fig. 12 over the (DR, SQNR)
//! plane, printing the energy-optimal architecture + granularity per spec
//! point as an ASCII map.
//!
//!     cargo run --release --example design_space [--samples N]

use grcim::energy::{CimArch, TechParams};
use grcim::figures::fig12::{evaluate_points, SpecPoint, ENERGY_CAP_FJ};
use grcim::figures::FigureCtx;

fn main() -> anyhow::Result<()> {
    let samples: usize = std::env::args()
        .skip_while(|a| a != "--samples")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8192);

    let ctx = FigureCtx::default();
    let tech = TechParams::default();

    // coarse grid: DR 3..15 bits, SQNR (N_M_eff) 1..7
    let drs: Vec<f64> = (3..=15).map(|d| d as f64).collect();
    let nms: Vec<f64> = (1..=7).map(|m| m as f64).collect();
    let mut points = Vec::new();
    for &nm in &nms {
        for &dr in &drs {
            points.push(SpecPoint { dr_bits: dr, n_m_eff: nm });
        }
    }
    let results = evaluate_points(&ctx, &points, samples, &tech)?;

    println!(
        "energy-optimal architecture per (DR, SQNR) spec point \
         ({samples} MC samples/point)\n"
    );
    println!("  legend: .=invalid  C=conventional  I=gr-int  R=gr-row  U=gr-unit");
    println!("          lowercase = best option exceeds {ENERGY_CAP_FJ} fJ/Op\n");
    println!("  SQNR(dB)");
    for (mi, &nm) in nms.iter().enumerate().rev() {
        let sqnr = 6.02 * nm + 10.79;
        let mut line = format!("  {sqnr:5.1} | ");
        for di in 0..drs.len() {
            let r = &results[mi * drs.len() + di];
            let ch = match r {
                None => '.',
                Some(p) => {
                    let conv = p.e_conv.total();
                    let (best, energy) = match &p.gr_best {
                        Some((arch, _, b)) if b.total() < conv => {
                            let c = match arch {
                                CimArch::GrInt => 'I',
                                CimArch::GrRow => 'R',
                                CimArch::GrUnit => 'U',
                                CimArch::Conventional => 'C',
                            };
                            (c, b.total())
                        }
                        _ => ('C', conv),
                    };
                    if energy > ENERGY_CAP_FJ {
                        best.to_ascii_lowercase()
                    } else {
                        best
                    }
                }
            };
            line.push(ch);
            line.push(' ');
        }
        println!("{line}");
    }
    let axis: Vec<String> =
        drs.iter().map(|d| format!("{:.0}", 6.02 * d)).collect();
    println!("        +-{}", "--".repeat(drs.len()));
    println!("          {}  DR(dB)", axis.join(" "));
    println!(
        "\nShape to see: conventional survives only near the diagonal (the\n\
         INT line); gain-ranging regions (I -> R/U) open up the wide-DR half\n\
         of the plane, until the gain stage's native range runs out\n\
         (lowercase / '.')."
    );
    Ok(())
}
