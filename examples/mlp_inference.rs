//! End-to-end driver (DESIGN.md §e2e): train a small MLP from scratch,
//! then run its inference entirely through the simulated mixed-signal CIM
//! array — every layer matmul tiled into NR-row column MACs, executed by
//! the AOT-compiled Pallas signal chain on the PJRT runtime (or the Rust
//! oracle with --engine rust), digitized at the spec-solved ADC
//! resolution, and priced with the paper's energy model.
//!
//! This proves the three layers compose: L1 Pallas kernel -> L2 HLO
//! artifact -> L3 Rust coordinator, with no Python at inference time.
//!
//!     cargo run --release --example mlp_inference [--engine rust|pjrt|auto]
//!
//! Results are recorded in EXPERIMENTS.md §e2e.

use grcim::coordinator::{run_experiment, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::energy::{energy_per_op, CimArch, TechParams};
use grcim::formats::FpFormat;
use grcim::mac::FormatPair;
use grcim::nn::{accuracy, cim_accuracy, make_blobs, CimInference, Mlp};
use grcim::report::Table;
use grcim::rng::Pcg64;
use grcim::runtime::{build_engine, ArtifactRegistry, EngineKind};
use grcim::spec::{required_enob, Arch, SpecConfig};
use grcim::util::Timer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let engine_kind = args
        .iter()
        .position(|a| a == "--engine")
        .and_then(|i| args.get(i + 1))
        .map(|s| EngineKind::parse(s))
        .transpose()?
        .unwrap_or(EngineKind::Auto);

    // ---- data + training (from-scratch substrate, no deps) ----
    let (dim, classes, hidden) = (32usize, 8usize, 64usize);
    let (xs, ys) = make_blobs(4096, dim, classes, 0.35, 11);
    let (train_x, test_x) = xs.split_at(3072);
    let (train_y, test_y) = ys.split_at(3072);

    let mut mlp = Mlp::new(&[dim, hidden, classes], 5);
    let mut rng = Pcg64::seeded(17);
    let t = Timer::new("train");
    let mut loss = f64::NAN;
    for epoch in 0..40 {
        loss = mlp.train_epoch(train_x, train_y, 0.05, &mut rng);
        if epoch % 10 == 0 {
            println!("epoch {epoch:>2}  loss {loss:.4}");
        }
    }
    println!("trained 40 epochs in {:.1}s, final loss {loss:.4}", t.elapsed_s());
    let float_acc = accuracy(&mlp, test_x, test_y);
    println!("float32 test accuracy: {:.1}%", 100.0 * float_acc);

    // ---- engine + ADC spec ----
    let engine = build_engine(engine_kind, &ArtifactRegistry::default_dir())?;
    println!("inference engine: {}", engine.name());
    let fmts = FormatPair::new(FpFormat::fp6_e2m3(), FpFormat::fp6_e2m3());
    let nr = 32;

    // dimension the ADC on the actual activation statistics (clipped
    // Gaussians are a fine stand-in for post-ReLU blob activations)
    let spec = ExperimentSpec {
        id: "mlp-dimensioning".into(),
        fmts,
        dist_x: Distribution::clipped_gauss4(),
        dist_w: Distribution::clipped_gauss4(),
        nr,
        samples: 16_384,
    };
    let agg = run_experiment(engine.as_ref(), &spec, 23)?;
    let cfg = SpecConfig::default();
    let enob_conv = required_enob(&agg, Arch::Conventional, cfg).enob;
    let enob_gr = required_enob(&agg, Arch::GrUnit, cfg).enob;
    println!(
        "spec-solved ADC: conventional {enob_conv:.2} b, gr-unit {enob_gr:.2} b"
    );

    // ---- CIM inference at each architecture's own ADC spec ----
    let tech = TechParams::default();
    let n_test = 512.min(test_x.len());
    let mut table = Table::new(
        "e2e results (FP6_E2M3, 32x32 tiles)",
        &["configuration", "adc_enob", "accuracy_pct", "energy_fj_per_op", "rel_energy"],
    );
    table.row(vec![
        "float32 reference".into(),
        "-".into(),
        format!("{:.1}", 100.0 * float_acc),
        "-".into(),
        "-".into(),
    ]);

    let mut e_conv_total = f64::NAN;
    for (label, arch, cim_arch, enob) in [
        ("conventional CIM", Arch::Conventional, CimArch::Conventional, enob_conv),
        ("GR-CIM (unit norm)", Arch::GrUnit, CimArch::GrUnit, enob_gr),
    ] {
        let t = Timer::new(label);
        let cim = CimInference { fmts, arch, enob, nr, nc: nr };
        let acc = cim_accuracy(
            &mlp,
            engine.as_ref(),
            &cim,
            &test_x[..n_test],
            &test_y[..n_test],
        )?;
        let e = energy_per_op(cim_arch, fmts, nr, nr, enob, &tech).total();
        if matches!(arch, Arch::Conventional) {
            e_conv_total = e;
        }
        println!(
            "{label}: {:.1}% on {n_test} samples in {:.1}s",
            100.0 * acc,
            t.elapsed_s()
        );
        table.row(vec![
            label.into(),
            Table::f(enob),
            format!("{:.1}", 100.0 * acc),
            Table::f(e),
            format!("{:.2}x", e / e_conv_total),
        ]);
    }
    println!("\n{}", table.to_markdown());
    println!(
        "Headline: iso-accuracy inference at a lower modeled energy/op —\n\
         the GR-MAC's relaxed ADC is the whole difference."
    );
    Ok(())
}
