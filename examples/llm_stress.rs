//! LLM activation stress test — the paper's motivating workload.
//!
//! Emulates the emergent-outlier statistics of LLM activations
//! (LLM.int8()/SmoothQuant/AWQ: ~1% outliers at ~50x the core's 3-sigma)
//! and sweeps input exponent bits, showing how the conventional CIM's ADC
//! requirement explodes once the format is wide enough to resolve the core
//! while the GR-MAC's stays nearly flat — the ">6 bit" headline of
//! Fig. 10.
//!
//!     cargo run --release --example llm_stress

use grcim::coordinator::{run_campaign, CampaignConfig, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::energy::{energy_per_op, CimArch, TechParams};
use grcim::formats::FpFormat;
use grcim::mac::FormatPair;
use grcim::report::Table;
use grcim::spec::{required_enob, Arch, SpecConfig};

fn main() -> anyhow::Result<()> {
    let weights = FpFormat::fp4_e2m1();
    let nr = 32;
    let specs: Vec<ExperimentSpec> = (1..=5)
        .map(|n_e| {
            let fmt = FpFormat::fp(n_e, 2);
            ExperimentSpec {
                id: format!("llm-ne{n_e}"),
                fmts: FormatPair::new(fmt, weights),
                dist_x: Distribution::gauss_outliers(),
                dist_w: Distribution::max_entropy(weights),
                nr,
                samples: 32_768,
            }
        })
        .collect();

    let cfg = CampaignConfig::default(); // auto engine, all cores
    let aggs = run_campaign(&specs, &cfg)?;

    let tech = TechParams::default();
    let scfg = SpecConfig::default();
    let mut t = Table::new(
        "LLM-activation stress (gauss + 1% outliers @ 50x 3sigma)",
        &[
            "input", "dr_db", "enob_conv", "enob_gr", "delta_bits",
            "e_conv_fj_op", "e_gr_fj_op",
        ],
    );
    for (spec, agg) in specs.iter().zip(&aggs) {
        let conv = required_enob(agg, Arch::Conventional, scfg).enob;
        let gr = required_enob(agg, Arch::GrUnit, scfg).enob;
        let e_conv =
            energy_per_op(CimArch::Conventional, spec.fmts, nr, nr, conv, &tech);
        let e_gr = energy_per_op(CimArch::GrUnit, spec.fmts, nr, nr, gr, &tech);
        t.row(vec![
            spec.fmts.x.to_string(),
            Table::f(spec.fmts.x.dr_db()),
            Table::f(conv),
            Table::f(gr),
            Table::f(conv - gr),
            Table::f(e_conv.total()),
            Table::f(e_gr.total()),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "Once the format resolves the activation core (N_E >= 3), the\n\
         conventional ADC pays for the full outlier dynamic range at every\n\
         conversion; local normalization does not. That gap is the paper's\n\
         '>6 bits / >4^6 ADC energy' claim."
    );
    Ok(())
}
