//! Quickstart: simulate one GR-CIM column, solve its ADC spec, and price
//! it with the paper's energy model — the 60-second tour of the public
//! API.
//!
//!     cargo run --release --example quickstart

use grcim::coordinator::{run_experiment, ExperimentSpec};
use grcim::distributions::Distribution;
use grcim::energy::{energy_per_op, CimArch, TechParams};
use grcim::formats::FpFormat;
use grcim::mac::FormatPair;
use grcim::runtime::{build_engine, ArtifactRegistry, EngineKind};
use grcim::spec::{required_enob, Arch, SpecConfig};

fn main() -> anyhow::Result<()> {
    // 1. Pick formats: FP6_E3M2 activations, FP4_E2M1 weights (OCP MX).
    let fmts = FormatPair::new(FpFormat::fp6_e3m2(), FpFormat::fp4_e2m1());
    println!(
        "input {} (DR {:.1} dB, SQNR {:.1} dB), weights {}",
        fmts.x,
        fmts.x.dr_db(),
        fmts.x.sqnr_db(),
        fmts.w
    );

    // 2. Build an engine: PJRT (AOT artifacts) if available, else the
    //    pure-Rust oracle. Python is never on this path.
    let engine = build_engine(EngineKind::Auto, &ArtifactRegistry::default_dir())?;
    println!("engine: {}", engine.name());

    // 3. Monte-Carlo one column experiment: LLM-style activations
    //    (Gaussian core + 1% outliers at 50x), max-entropy weights,
    //    32-deep array.
    let spec = ExperimentSpec {
        id: "quickstart".into(),
        fmts,
        dist_x: Distribution::gauss_outliers(),
        dist_w: Distribution::max_entropy(fmts.w),
        nr: 32,
        samples: 16_384,
    };
    let agg = run_experiment(engine.as_ref(), &spec, 42)?;
    println!(
        "simulated {} samples: N_eff = {:.1} (of NR = 32), \
         GR/conv ADC-input power gain = {:.1}x",
        agg.samples(),
        agg.mean_n_eff(),
        agg.signal_power_gain()
    );

    // 4. Solve the ADC requirement for each architecture.
    let cfg = SpecConfig::default();
    let conv = required_enob(&agg, Arch::Conventional, cfg);
    let unit = required_enob(&agg, Arch::GrUnit, cfg);
    let row = required_enob(&agg, Arch::GrRow, cfg);
    println!(
        "required ENOB: conventional {:.2} b | gr-row {:.2} b | gr-unit {:.2} b",
        conv.enob, row.enob, unit.enob
    );

    // 5. Price it (28 nm, 0.9 V — the paper's Table III).
    let tech = TechParams::default();
    for (arch, enob) in [
        (CimArch::Conventional, conv.enob),
        (CimArch::GrRow, row.enob),
        (CimArch::GrUnit, unit.enob),
    ] {
        let e = energy_per_op(arch, fmts, 32, 32, enob, &tech);
        println!(
            "{:<13} {:6.1} fJ/Op  (adc {:5.1}, dac {:4.1}, cells {:4.1}, logic {:4.1})",
            arch.name(),
            e.total(),
            e.adc,
            e.dac,
            e.cells,
            e.exp_logic + e.tree + e.norm_mult,
        );
    }
    println!("\n(The GR rows undercut the conventional row on this workload — that is the paper.)");
    Ok(())
}
