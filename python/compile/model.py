"""L2: the JAX compute graph lowered to the AOT artifacts.

The paper's "model" is the mixed-signal CIM array itself: the compute graph
quantizes a Monte-Carlo batch of (activation, weight) row pairs to a runtime
FP format and pushes it through both analog signal chains (conventional
FP->INT and GR-MAC), emitting the per-sample statistics the Rust coordinator
aggregates into ADC-resolution and energy results.

Two entry points are lowered per array depth NR:

  macsim   — the statistics path used by the figure campaigns
             (B=2048 samples/batch).
  mvmsim   — the same graph at a smaller batch, used by the end-to-end MLP
             inference example, where each "sample" is one output column of
             a 32x32 CIM tile (B=32).

Both call the fused L1 Pallas kernel (`kernels.grmac`); `interpret=True` is
mandatory on the CPU PJRT plugin (Mosaic custom-calls are TPU-only).
Python never runs at inference/campaign time: these graphs are lowered once
by `aot.py` into `artifacts/*.hlo.txt`.
"""

import jax.numpy as jnp

from .kernels import grmac

# Batch of one statistics artifact execution. 2048 keeps each PJRT call's
# working set ~2 MiB while amortizing dispatch overhead measured on the
# Rust side (see EXPERIMENTS.md §Perf).
BATCH = 2048
# Supported array depths; one artifact per depth (shapes are static in HLO).
ARRAY_DEPTHS = (16, 32, 64, 128)
# Batch of the MVM-tile artifact (one sample per output column of a tile).
MVM_BATCH = 32


def macsim(x, w, fmt):
    """Statistics graph: tuple of eight f32[B] outputs (see kernels.ref)."""
    return grmac.simulate_column(x, w, fmt, interpret=True)


def mvmsim(x, w, fmt):
    """MVM-tile graph: identical math at the e2e example's tile batch."""
    return grmac.simulate_column(x, w, fmt, interpret=True)
