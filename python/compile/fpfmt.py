"""Floating-point format arithmetic for the GR-CIM signal-chain simulation.

Implements the paper's value convention (Sec. III-A):

    x = (-1)^S * M * 2^(E - E_max),   E_max = 2^N_E - 1

with the *effective* significand M in [0.5, 1) for normals
(M = 1.M_stored / 2), M in [0, 0.5) for subnormals (stored exponent code 0,
effective exponent E = 1), and the effective exponent E = max(1, E_stored).

All format parameters are **runtime f32 scalars** so a single lowered HLO
module serves the entire format sweep; only array shapes are baked at AOT
time. Formats are parameterized by (e_max, n_m) rather than (N_E, N_M):
e_max = 2^N_E - 1 for integer exponent widths, but the Fig. 12 design-space
grid also uses fractional e_max (a continuous dynamic-range axis) and
fractional n_m (a continuous SQNR axis); the quantizer remains well-defined
for both (the exponent grid stays integer-stepped, offset by e_max).

Rounding is floor(m/step + 0.5) (round-half-up) so the Rust f64 oracle in
`rust/src/formats/` can match bit-for-bit at f32-representable points.
"""

import jax
import jax.numpy as jnp

# Smallest positive f32 normal; guards log2(0) without perturbing any
# representable magnitude of interest (formats here have E_max <= 31).
_TINY = 1e-30


def exp2(t):
    """Bit-exact 2^t for integer t, standard exp2 on the fractional part.

    XLA-CPU's f32 exp2 is inexact even at integer arguments (e.g.
    exp2(13.0) -> 8192.0039), which corrupts the power-of-two scalings this
    whole simulation is built on. The integer part is constructed directly
    in the f32 exponent field ((ti+127)<<23 bitcast), which is exact; only
    genuinely fractional exponents (the Fig. 12 continuous axes) go through
    the approximate exp2. The Rust oracle mirrors these semantics in f64.
    """
    ti = jnp.floor(t)
    fr = t - ti
    ti = jnp.clip(ti, -126.0, 127.0)
    ip = jax.lax.bitcast_convert_type(
        (ti.astype(jnp.int32) + 127) << 23, jnp.float32
    )
    return ip * jnp.exp2(fr)


def fmt_consts(n_m):
    """Derived mantissa-grid constants.

    Returns (step, vmax):
      step: mantissa grid step on the effective significand M in [0,1),
            2^-(N_M+1)  (N_M stored bits + the implicit leading bit,
            divided by 2 per the M = 1.M/2 convention).
      vmax: largest representable magnitude, (1 - step) * 2^0.
    """
    step = exp2(-(n_m + 1.0))
    vmax = 1.0 - step
    return step, vmax


def decompose(a, e_max):
    """Split magnitudes `a` into (M, E_eff) per the paper's convention.

    a == 0 maps to (0.0, 1.0) — the zero encoding keeps the subnormal
    exponent, which matters for the GR-MAC: a zero-mantissa cell still
    drives its one-hot exponent coupling switches (Sec. III-B2).
    """
    safe = jnp.maximum(a, _TINY)
    e = jnp.floor(jnp.log2(safe)) + 1.0 + e_max
    e = jnp.clip(e, 1.0, e_max)
    m = a * exp2(e_max - e)
    return m, e


def quantize(x, e_max, n_m):
    """Quantize `x` to FP(e_max, N_M): round-half-up on the mantissa grid,
    saturating at +/- vmax. Values below the subnormal grid flush toward 0
    on the same grid (step * 2^(1 - e_max))."""
    step, vmax = fmt_consts(n_m)
    s = jnp.sign(x)
    a = jnp.abs(x)
    m, e = decompose(a, e_max)
    m_q = jnp.floor(m / step + 0.5) * step
    # m_q == 1.0 rollover re-normalizes to 0.5 * 2^(e+1); representable as
    # long as e < e_max, and the vmax clamp saturates the e == e_max case.
    a_q = jnp.minimum(m_q * exp2(e - e_max), vmax)
    return s * a_q


def ulp(a_q, e_max, n_m):
    """Local quantization step of the format at quantized magnitude a_q:
    Delta = step * 2^(E_eff - e_max). This is the per-value noise-floor
    ingredient of the ADC spec (Sec. IV-A / DESIGN.md #6)."""
    step, _ = fmt_consts(n_m)
    _, e = decompose(a_q, e_max)
    return step * exp2(e - e_max)
