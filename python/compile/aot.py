"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per (graph, NR):
    macsim_nr{16,32,64,128}.hlo.txt   f32[2048,NR] x, w; f32[4] fmt
    mvmsim_nr{16,32,64,128}.hlo.txt   f32[32,NR]   x, w; f32[4] fmt
plus `manifest.json` describing shapes so the Rust artifact registry can
validate what it loads.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, batch: int, nr: int) -> str:
    x = jax.ShapeDtypeStruct((batch, nr), jnp.float32)
    w = jax.ShapeDtypeStruct((batch, nr), jnp.float32)
    fmt = jax.ShapeDtypeStruct((4,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(x, w, fmt))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--depths",
        default=",".join(str(d) for d in model.ARRAY_DEPTHS),
        help="comma-separated NR values",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    depths = [int(d) for d in args.depths.split(",")]
    manifest = {"batch": model.BATCH, "mvm_batch": model.MVM_BATCH,
                "outputs": 11, "entries": []}
    for nr in depths:
        for name, fn, batch in (
            ("macsim", model.macsim, model.BATCH),
            ("mvmsim", model.mvmsim, model.MVM_BATCH),
        ):
            text = lower_entry(fn, batch, nr)
            fname = f"{name}_nr{nr}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {"file": fname, "graph": name, "nr": nr, "batch": batch}
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
