"""Pure-jnp oracle for the GR-MAC / INT-MAC signal-chain simulation.

This is the correctness reference the Pallas kernel (`grmac.py`) is tested
against, and the semantic twin of the pure-Rust engine in `rust/src/mac/`.

One "column simulation" evaluates, for a batch of (x, w) row-vector pairs,
both analog signal chains of the paper with an infinite-precision ADC and
returns everything the host needs to solve the required ADC ENOB in closed
form (DESIGN.md Sec. 5):

  z_ideal : (1/NR) sum x*w               — unquantized dot product
  z_q     : (1/NR) sum x_q*w_q           — quantized-input dot product
                                           (all signal chains are linear, so
                                           this is the infinite-ADC output of
                                           every architecture)
  v_conv  : conventional compute-line voltage after FP->INT mantissa
            alignment to the per-block max exponents (the conventional ADC
            input; |v_conv| <= 1)
  g_conv  : conventional digital rescale 2^(E_bx + E_bw - E_max,x - E_max,w)
            — the per-sample gain through which ADC noise refers to the
            output
  v_gr    : GR-MAC (unit-normalization) column voltage
            sum(s*Mp*2^ep) / sum(2^ep) (the GR ADC input; exponent-weighted
            average, |v_gr| <= 1)
  s_sum   : S  = sum(2^(ep - ep_max)) — unit-norm normalization factor; the
            unit-granularity noise-referral gain is g_unit = S / NR
  s2_sum  : S2 = sum(4^(ep - ep_max)) — N_eff = S^2/S2 ingredient
  sx_sum  : S_x = sum(2^(ex - e_max,x)) — row-normalization factor (inputs
            normalized, weights block-aligned); g_row = g_w * S_x / NR
  g_w     : 2^(E_bw - E_max,w) — the weight-block rescale used by both the
            conventional and the row-normalized paths
  nf      : output-referred **input** quantization noise floor of the FP
            representation (1/(12 NR^2)) sum(w_q^2 ulp_x^2). Input-side
            only: the paper's ADC spec protects the input format's
            fidelity ("only input quantization noise is considered",
            Fig. 10 caption) — weight quantization is part of the model,
            not noise. This is the GR-side floor; the conventional CIM is
            dimensioned for the *aligned INT grid* instead (its floor is
            reconstructed host-side from wq2_mean and the format's
            minimum step — see rust spec::required_enob).
  wq2_mean: per-sample mean of w_q^2 — the conventional INT-grid floor
            ingredient.

Format vector: fmt = f32[4] = [e_max_x, n_m_x, e_max_w, n_m_w]; e_max may be
fractional (continuous dynamic-range axis of the Fig. 12 design-space map).
"""

import jax.numpy as jnp

from ..fpfmt import decompose, exp2, fmt_consts, quantize


def simulate_column(x, w, fmt):
    """Reference signal-chain simulation.

    Args:
      x, w: f32[B, NR] raw (pre-quantization) activations and weights.
      fmt:  f32[4] = [e_max_x, n_m_x, e_max_w, n_m_w].

    Returns: tuple of ten f32[B] arrays (see module docstring).
    """
    emx, n_m_x, emw, n_m_w = fmt[0], fmt[1], fmt[2], fmt[3]
    nr = x.shape[-1]
    stx, _ = fmt_consts(n_m_x)
    stw, _ = fmt_consts(n_m_w)

    xq = quantize(x, emx, n_m_x)
    wq = quantize(w, emw, n_m_w)
    sx, sw = jnp.sign(xq), jnp.sign(wq)
    mx, ex = decompose(jnp.abs(xq), emx)
    mw, ew = decompose(jnp.abs(wq), emw)

    z_ideal = jnp.mean(x * w, axis=-1)
    z_q = jnp.mean(xq * wq, axis=-1)

    # Conventional FP->INT path: mantissa alignment to the block-wise max
    # effective exponent (x and w blocks normalized independently), uniform
    # charge averaging on the compute line, digital rescale after the ADC.
    ebx = jnp.max(ex, axis=-1, keepdims=True)
    ebw = jnp.max(ew, axis=-1, keepdims=True)
    xint = sx * mx * exp2(ex - ebx)
    wint = sw * mw * exp2(ew - ebw)
    v_conv = jnp.mean(xint * wint, axis=-1)
    g_w = exp2(ebw[..., 0] - emw)
    g_conv = exp2(ebx[..., 0] - emx) * g_w

    # GR-MAC unit-normalization path: normalized mantissa product per cell,
    # coupling capacitance proportional to 2^(E_x + E_w); the column voltage
    # is the exponent-weighted average; S is the digital normalization
    # factor produced by the column exponent adder tree.
    u = exp2(ex + ew - emx - emw)  # in (0, 1], max code -> 1
    s_sum = jnp.sum(u, axis=-1)
    s2_sum = jnp.sum(u * u, axis=-1)
    v_gr = jnp.sum(sx * sw * mx * mw * u, axis=-1) / s_sum

    # Row normalization: only the input exponent drives the gain-ranging
    # stage; weights are stored block-aligned (as in the conventional path).
    ux = exp2(ex - emx)
    sx_sum = jnp.sum(ux, axis=-1)

    # Ulp-based *input* noise floor referred to the output (exact for
    # max-entropy inputs where the empirical quantization error is zero).
    # Input-side only per the paper's ADC spec (Fig. 10 caption).
    dx = stx * exp2(ex - emx)
    nf = jnp.sum(wq * wq * dx * dx, axis=-1) / (12.0 * nr * nr)
    wq2_mean = jnp.mean(wq * wq, axis=-1)

    return (
        z_ideal, z_q, v_conv, g_conv, v_gr, s_sum, s2_sum, sx_sum, g_w, nf,
        wq2_mean,
    )
