"""L1 Pallas kernel: fused GR-MAC / INT-MAC Monte-Carlo column simulation.

One fused kernel evaluates, per (TILE_B, NR) block, the full signal chain of
the paper's architectures — FP quantization, mantissa/exponent
decomposition, FP->INT mantissa alignment (conventional path),
exponent-weighted gain-ranged accumulation (GR unit- and row-normalization
paths), and the ulp-based noise-floor reduction — producing the ten
per-sample statistics defined in `ref.py`.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch dimension is tiled
with BlockSpec into (TILE_B, NR) VMEM-resident blocks; NR <= 128 keeps the
reduction axis within one lane register tile, all math is elementwise
exp2/log2/floor (VPU-bound), and the ten reductions stay in-registers — no
HBM round-trips between stages. On this image the kernel runs under
`interpret=True` (the CPU PJRT plugin cannot execute Mosaic custom-calls),
so performance is assessed structurally: a single pallas_call, zero
intermediate materialization.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fpfmt import decompose, exp2, fmt_consts, quantize

# 2048-sample batches split into 8 tiles: each f32 operand block is
# 256*128*4 B = 128 KiB at the largest supported NR, comfortably in VMEM.
TILE_B = 256

N_OUTPUTS = 11


def _kernel(
    x_ref,
    w_ref,
    fmt_ref,
    z_ideal_ref,
    z_q_ref,
    v_conv_ref,
    g_conv_ref,
    v_gr_ref,
    s_sum_ref,
    s2_sum_ref,
    sx_sum_ref,
    g_w_ref,
    nf_ref,
    wq2_mean_ref,
):
    x = x_ref[...]
    w = w_ref[...]
    emx = fmt_ref[0]
    n_m_x = fmt_ref[1]
    emw = fmt_ref[2]
    n_m_w = fmt_ref[3]
    nr = x.shape[-1]

    stx, _ = fmt_consts(n_m_x)
    stw, _ = fmt_consts(n_m_w)

    xq = quantize(x, emx, n_m_x)
    wq = quantize(w, emw, n_m_w)
    sx, sw = jnp.sign(xq), jnp.sign(wq)
    mx, ex = decompose(jnp.abs(xq), emx)
    mw, ew = decompose(jnp.abs(wq), emw)

    z_ideal_ref[...] = jnp.mean(x * w, axis=-1)
    z_q_ref[...] = jnp.mean(xq * wq, axis=-1)

    ebx = jnp.max(ex, axis=-1, keepdims=True)
    ebw = jnp.max(ew, axis=-1, keepdims=True)
    xint = sx * mx * exp2(ex - ebx)
    wint = sw * mw * exp2(ew - ebw)
    v_conv_ref[...] = jnp.mean(xint * wint, axis=-1)
    g_w = exp2(ebw[..., 0] - emw)
    g_w_ref[...] = g_w
    g_conv_ref[...] = exp2(ebx[..., 0] - emx) * g_w

    u = exp2(ex + ew - emx - emw)
    s_sum = jnp.sum(u, axis=-1)
    s_sum_ref[...] = s_sum
    s2_sum_ref[...] = jnp.sum(u * u, axis=-1)
    v_gr_ref[...] = jnp.sum(sx * sw * mx * mw * u, axis=-1) / s_sum

    ux = exp2(ex - emx)
    sx_sum_ref[...] = jnp.sum(ux, axis=-1)

    dx = stx * exp2(ex - emx)
    nf_ref[...] = jnp.sum(wq * wq * dx * dx, axis=-1) / (12.0 * nr * nr)
    wq2_mean_ref[...] = jnp.mean(wq * wq, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def simulate_column(x, w, fmt, interpret=True):
    """Pallas-fused equivalent of `ref.simulate_column`.

    Args:
      x, w: f32[B, NR] with B a multiple of TILE_B (or B < TILE_B, in which
            case a single tile of size B is used).
      fmt:  f32[4] = [e_max_x, n_m_x, e_max_w, n_m_w].

    Returns: tuple of ten f32[B] arrays (see ref.py).
    """
    b, nr = x.shape
    tile = min(TILE_B, b)
    if b % tile != 0:
        raise ValueError(f"batch {b} not a multiple of tile {tile}")
    grid = (b // tile,)

    in_specs = [
        pl.BlockSpec((tile, nr), lambda i: (i, 0)),
        pl.BlockSpec((tile, nr), lambda i: (i, 0)),
        pl.BlockSpec((4,), lambda i: (0,)),
    ]
    vec = jax.ShapeDtypeStruct((b,), jnp.float32)
    out_specs = [pl.BlockSpec((tile,), lambda i: (i,))] * N_OUTPUTS

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[vec] * N_OUTPUTS,
        interpret=interpret,
    )(x, w, fmt)
