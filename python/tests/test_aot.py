"""AOT lowering sanity: HLO text is parseable interchange, manifest is
consistent, and the lowered module has the expected I/O signature."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def hlo_text():
    return aot.lower_entry(model.macsim, 256, 32)


def test_hlo_text_has_entry_computation(hlo_text):
    assert "ENTRY" in hlo_text
    assert "HloModule" in hlo_text


def test_hlo_text_io_signature(hlo_text):
    # params: f32[256,32] x2 and f32[4]; result: 10-tuple of f32[256]
    assert "f32[256,32]" in hlo_text
    assert "f32[4]" in hlo_text
    assert hlo_text.count("f32[256]{0}") >= model.N_OUTPUTS if hasattr(
        model, "N_OUTPUTS"
    ) else "f32[256]" in hlo_text


def test_hlo_is_text_not_proto(hlo_text):
    # the interchange gotcha: must be human-readable text, never proto bytes
    assert hlo_text.isprintable() or "\n" in hlo_text
    assert not hlo_text.startswith(b"\x08".decode("latin1"))


def test_no_custom_calls_in_lowered_module(hlo_text):
    # interpret=True must lower pallas to plain HLO — a Mosaic custom-call
    # would be unloadable by the CPU PJRT plugin
    assert "custom-call" not in hlo_text.lower() or "mosaic" not in hlo_text.lower()


def test_artifacts_manifest_consistent_if_present():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as f:
        man = json.load(f)
    assert man["outputs"] == 11
    for entry in man["entries"]:
        path = os.path.join(art, entry["file"])
        assert os.path.exists(path), entry["file"]
        with open(path) as f:
            text = f.read()
        assert "ENTRY" in text
        assert f"f32[{entry['batch']},{entry['nr']}]" in text
