"""Unit + property tests for the runtime-parameterized FP quantizer."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.fpfmt import decompose, fmt_consts, quantize, ulp


def q(x, e_max, n_m):
    return np.asarray(quantize(jnp.float32(x), e_max, n_m))


# --- exact code books -------------------------------------------------------

FP4_E2M1 = sorted(
    {0.0, 0.0625, 0.125, 0.1875, 0.25, 0.375, 0.5, 0.75}
    | {-v for v in (0.0625, 0.125, 0.1875, 0.25, 0.375, 0.5, 0.75)}
)


def codebook(e_max, n_m):
    """Enumerate all representable magnitudes of FP(e_max, n_m)."""
    step = 2.0 ** -(n_m + 1)
    vals = set()
    # subnormals at effective exponent 1
    for k in range(int(round(0.5 / step))):
        vals.add(k * step * 2.0 ** (1 - e_max))
    for e in range(1, e_max + 1):
        m = 0.5
        while m < 1.0 - 1e-12:
            vals.add(m * 2.0 ** (e - e_max))
            m += step
    return sorted(vals)


def test_fp4_e2m1_codebook_matches_ocp_values():
    # FP4 E2M1 scaled by 8 is the OCP MX set {0,.5,1,1.5,2,3,4,6}
    mags = codebook(3, 1)
    assert np.allclose(np.array(mags) * 8, [0, 0.5, 1, 1.5, 2, 3, 4, 6])


@pytest.mark.parametrize("e_max,n_m", [(3, 1), (3, 3), (7, 2), (1, 2), (15, 3)])
def test_codebook_values_are_fixed_points(e_max, n_m):
    for v in codebook(e_max, n_m):
        assert q(v, e_max, n_m) == pytest.approx(v, abs=0), v
        assert q(-v, e_max, n_m) == pytest.approx(-v, abs=0), v


@pytest.mark.parametrize("e_max,n_m", [(3, 1), (3, 3), (7, 2)])
def test_quantize_snaps_to_nearest_codebook_entry(e_max, n_m):
    book = np.array(codebook(e_max, n_m))
    rng = np.random.default_rng(42)
    xs = rng.uniform(0, 1, 300).astype(np.float32)
    for x in xs:
        got = float(q(x, e_max, n_m))
        best = book[np.argmin(np.abs(book - min(x, book[-1])))]
        # round-half-up can differ from argmin at exact midpoints only
        err_got = abs(got - min(x, book[-1]))
        err_best = abs(best - min(x, book[-1]))
        assert err_got <= err_best + 1e-7


def test_saturation_at_vmax():
    assert q(5.0, 3, 1) == pytest.approx(0.75)
    assert q(-5.0, 3, 1) == pytest.approx(-0.75)
    assert q(1.0, 3, 3) == pytest.approx(1.0 - 2.0**-4)


def test_zero_is_preserved():
    assert q(0.0, 3, 1) == 0.0
    assert q(-0.0, 7, 3) == 0.0


def test_subnormal_flush():
    # FP4_E2M1 subnormal grid step = 0.0625; below half of it -> 0
    assert q(0.01, 3, 1) == 0.0
    assert q(0.05, 3, 1) == pytest.approx(0.0625)


def test_mantissa_rollover_renormalizes():
    # m rounds to 1.0 at a non-top exponent: 0.4999 with coarse mantissa
    # FP(e_max=3, n_m=1): 0.47 -> m=0.94 -> rounds to 1.0 -> 0.5 at e+1
    assert q(0.47, 3, 1) == pytest.approx(0.5)


def test_decompose_convention():
    m, e = decompose(jnp.float32(0.75), jnp.float32(3.0))
    assert float(m) == pytest.approx(0.75) and float(e) == 3.0
    m, e = decompose(jnp.float32(0.125), jnp.float32(3.0))  # 0.5 * 2^-2
    assert float(m) == pytest.approx(0.5) and float(e) == 1.0
    # subnormal: below 2^-e_max
    m, e = decompose(jnp.float32(0.0625), jnp.float32(3.0))
    assert float(e) == 1.0 and float(m) == pytest.approx(0.25)
    # zero keeps the subnormal exponent (drives coupling switches)
    m, e = decompose(jnp.float32(0.0), jnp.float32(3.0))
    assert float(m) == 0.0 and float(e) == 1.0


@given(
    x=st.floats(-1.0, 1.0, width=32),
    n_e=st.integers(1, 5),
    n_m=st.integers(1, 5),
)
@settings(max_examples=300, deadline=None)
def test_quantize_error_bounded_by_half_ulp_or_saturation(x, n_e, n_m):
    e_max = 2.0**n_e - 1
    xq = float(q(x, e_max, n_m))
    step, vmax = fmt_consts(jnp.float32(n_m))
    step, vmax = float(step), float(vmax)
    if abs(x) >= vmax:
        assert xq == math.copysign(vmax, x) or x == 0
    else:
        delta = float(ulp(jnp.float32(abs(xq)), e_max, n_m))
        # rounding error <= half the local step (+ f32 slack)
        assert abs(xq - x) <= 0.5 * delta * (1 + 1e-5) + 1e-7


@given(
    n_e=st.integers(1, 4),
    n_m=st.integers(1, 4),
    a=st.floats(0.0, 1.0, width=32),
    b=st.floats(0.0, 1.0, width=32),
)
@settings(max_examples=200, deadline=None)
def test_quantize_monotone(n_e, n_m, a, b):
    e_max = 2.0**n_e - 1
    lo, hi = min(a, b), max(a, b)
    assert float(q(lo, e_max, n_m)) <= float(q(hi, e_max, n_m))


@given(
    x=st.floats(-1.0, 1.0, width=32), n_e=st.integers(1, 4), n_m=st.integers(1, 4)
)
@settings(max_examples=200, deadline=None)
def test_quantize_idempotent_and_odd(x, n_e, n_m):
    e_max = 2.0**n_e - 1
    x1 = float(q(x, e_max, n_m))
    assert float(q(x1, e_max, n_m)) == x1
    assert float(q(-x, e_max, n_m)) == -x1


@given(xs=st.lists(st.floats(-1, 1, width=32), min_size=4, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_vectorized_matches_scalar(xs):
    arr = jnp.array(xs, dtype=jnp.float32)
    vec = np.asarray(quantize(arr, 7.0, 2.0))
    for xi, vi in zip(xs, vec):
        assert float(q(xi, 7, 2)) == vi


def test_fractional_format_is_well_defined():
    # fractional e_max / n_m used by the Fig. 12 continuous DR/SQNR grid
    xq = q(0.3, 5.5, 2.5)
    assert np.isfinite(xq)
    # still idempotent
    assert float(q(float(xq), 5.5, 2.5)) == pytest.approx(float(xq), rel=1e-6)
