"""Physical invariants of the simulated signal chains (L2 semantics).

These are the identities the Rust host relies on when it reconstructs
outputs and solves the ADC spec, so they are pinned here against the oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref

NAMES = [
    "z_ideal", "z_q", "v_conv", "g_conv", "v_gr",
    "s_sum", "s2_sum", "sx_sum", "g_w", "nf", "wq2_mean",
]


def sim(x, w, fmt):
    out = ref.simulate_column(jnp.array(x), jnp.array(w), jnp.array(fmt))
    return dict(zip(NAMES, [np.asarray(o) for o in out]))


def rand_case(seed, b=512, nr=32, dist="uniform"):
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        x = rng.uniform(-1, 1, (b, nr))
    elif dist == "gauss":
        x = np.clip(rng.normal(0, 0.25, (b, nr)), -1, 1)
    else:
        raise ValueError(dist)
    w = rng.uniform(-1, 1, (b, nr))
    return x.astype(np.float32), w.astype(np.float32)


FMT = np.array([3.0, 2.0, 3.0, 1.0], dtype=np.float32)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_linear_chain_identity(seed):
    """All architectures share the same infinite-ADC output:
    z_q == v_conv * g_conv == v_gr * S / NR."""
    x, w = rand_case(seed)
    d = sim(x, w, FMT)
    nr = x.shape[1]
    np.testing.assert_allclose(
        d["z_q"], d["v_conv"] * d["g_conv"], atol=1e-7, rtol=1e-5
    )
    np.testing.assert_allclose(
        d["z_q"], d["v_gr"] * d["s_sum"] / nr, atol=1e-7, rtol=1e-5
    )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_adc_inputs_within_full_scale(seed):
    x, w = rand_case(seed)
    d = sim(x, w, FMT)
    assert np.all(np.abs(d["v_conv"]) <= 1.0 + 1e-6)
    assert np.all(np.abs(d["v_gr"]) <= 1.0 + 1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_neff_bounds(seed):
    """1 <= N_eff = S^2/S2 <= NR (weighted-sample effective count)."""
    x, w = rand_case(seed, dist="gauss")
    d = sim(x, w, FMT)
    neff = d["s_sum"] ** 2 / d["s2_sum"]
    nr = x.shape[1]
    assert np.all(neff >= 1.0 - 1e-5)
    assert np.all(neff <= nr + 1e-3)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_referral_gains_bounded(seed):
    """g_conv, g_w <= 1 (block max can't exceed format max); S/NR <= 1."""
    x, w = rand_case(seed, dist="gauss")
    d = sim(x, w, FMT)
    nr = x.shape[1]
    for g in (d["g_conv"], d["g_w"], d["s_sum"] / nr, d["sx_sum"] / nr):
        assert np.all(g <= 1.0 + 1e-6)
        assert np.all(g > 0.0)


def test_gr_signal_preservation_vs_conventional():
    """Paper Sec. III-B2: for spread-exponent data the GR ADC input variance
    exceeds the conventional ADC input variance (signal preservation)."""
    x, w = rand_case(3, b=4096, dist="gauss")
    d = sim(x, w, FMT)
    assert np.var(d["v_gr"]) > 2.0 * np.var(d["v_conv"])


def test_noise_floor_positive_and_scales_with_coarser_mantissa():
    x, w = rand_case(5)
    fine = sim(x, w, np.array([3, 4, 3, 4], np.float32))
    coarse = sim(x, w, np.array([3, 1, 3, 1], np.float32))
    assert np.mean(coarse["nf"]) > 10 * np.mean(fine["nf"])
    assert np.all(fine["nf"] >= 0)


def test_quantization_error_consistent_with_noise_floor():
    """Empirical quantized-output error should be within an order of the
    ulp-based floor for a smooth input distribution. (The floor is
    input-side only; the empirical error also carries weight-quantization
    noise, so the ratio sits above 1 for coarse weights.)"""
    x, w = rand_case(8, b=8192)
    d = sim(x, w, FMT)
    emp = np.mean((d["z_q"] - d["z_ideal"]) ** 2)
    floor = np.mean(d["nf"])
    assert 0.2 < emp / floor < 40.0
