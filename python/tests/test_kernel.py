"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import grmac, ref

NAMES = [
    "z_ideal", "z_q", "v_conv", "g_conv", "v_gr",
    "s_sum", "s2_sum", "sx_sum", "g_w", "nf", "wq2_mean",
]


def run_both(x, w, fmt):
    r = ref.simulate_column(jnp.array(x), jnp.array(w), jnp.array(fmt))
    k = grmac.simulate_column(jnp.array(x), jnp.array(w), jnp.array(fmt))
    return [np.asarray(a) for a in r], [np.asarray(a) for a in k]


def assert_match(r, k, tol=1e-6):
    for name, a, b in zip(NAMES, r, k):
        np.testing.assert_allclose(a, b, atol=tol, rtol=tol, err_msg=name)


def make_fmt(n_e_x, n_m_x, n_e_w, n_m_w):
    return np.array(
        [2.0**n_e_x - 1, n_m_x, 2.0**n_e_w - 1, n_m_w], dtype=np.float32
    )


@pytest.mark.parametrize("nr", [16, 32, 64, 128])
@pytest.mark.parametrize("b", [256, 512])
def test_kernel_matches_ref_across_shapes(nr, b):
    rng = np.random.default_rng(nr * 1000 + b)
    x = rng.uniform(-1, 1, (b, nr)).astype(np.float32)
    w = rng.normal(0, 0.25, (b, nr)).astype(np.float32)
    r, k = run_both(x, w, make_fmt(2, 3, 2, 1))
    assert_match(r, k)


@given(
    n_e_x=st.integers(1, 5),
    n_m_x=st.integers(1, 5),
    n_e_w=st.integers(1, 4),
    n_m_w=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_kernel_matches_ref_across_formats(n_e_x, n_m_x, n_e_w, n_m_w, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, (256, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (256, 32)).astype(np.float32)
    r, k = run_both(x, w, make_fmt(n_e_x, n_m_x, n_e_w, n_m_w))
    assert_match(r, k)


def test_kernel_small_batch_single_tile():
    rng = np.random.default_rng(7)
    x = rng.uniform(-1, 1, (32, 16)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 16)).astype(np.float32)
    r, k = run_both(x, w, make_fmt(2, 2, 2, 1))
    assert_match(r, k)


def test_kernel_rejects_ragged_batch():
    x = np.zeros((300, 16), np.float32)
    with pytest.raises(ValueError):
        grmac.simulate_column(
            jnp.array(x), jnp.array(x), jnp.array(make_fmt(2, 2, 2, 1))
        )


def test_zero_inputs():
    x = np.zeros((256, 32), np.float32)
    w = np.zeros((256, 32), np.float32)
    r, k = run_both(x, w, make_fmt(2, 3, 2, 1))
    assert_match(r, k)
    d = dict(zip(NAMES, k))
    assert np.all(d["z_q"] == 0) and np.all(d["v_gr"] == 0)
    # all-zero cells still couple at the subnormal exponent: S > 0
    assert np.all(d["s_sum"] > 0)


def test_equal_exponent_worst_case_neff_equals_nr():
    # all values at the same exponent -> N_eff == NR (paper Sec. III-B2)
    nr = 32
    x = np.full((256, nr), 0.6, np.float32)  # e = e_max for any format
    w = np.full((256, nr), 0.55, np.float32)
    r, k = run_both(x, w, make_fmt(3, 2, 3, 2))
    d = dict(zip(NAMES, k))
    neff = d["s_sum"] ** 2 / d["s2_sum"]
    np.testing.assert_allclose(neff, nr, rtol=1e-6)


def test_fractional_formats_match():
    rng = np.random.default_rng(9)
    x = rng.uniform(-1, 1, (256, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (256, 32)).astype(np.float32)
    fmt = np.array([5.5, 2.25, 3.0, 1.0], dtype=np.float32)
    r, k = run_both(x, w, fmt)
    assert_match(r, k)


def test_extreme_and_saturating_inputs():
    rng = np.random.default_rng(11)
    x = rng.uniform(-10, 10, (256, 32)).astype(np.float32)  # saturates
    w = rng.uniform(-10, 10, (256, 32)).astype(np.float32)
    r, k = run_both(x, w, make_fmt(2, 1, 2, 1))
    assert_match(r, k)
